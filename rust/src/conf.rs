//! Cluster, system and cost-model configuration.
//!
//! The paper's cost model `C(P, cc)` is explicitly parameterised by a
//! cluster configuration `cc` (§3, R3). [`ClusterConfig`] captures the
//! paper's 1+6-node Hadoop testbed as its default; [`CostConstants`]
//! collects the white-box model constants (IO bandwidths, latencies, FLOP
//! correction factors) calibrated in DESIGN.md; [`SystemConfig`] holds the
//! compiler-level knobs (block size, memory budget ratio, #reducers).

/// Cluster characteristics `cc` used by the optimizer and the cost model.
///
/// Plan *shape* depends only on the heap sizes (through the §2 memory
/// budgets); every other field affects estimated *cost* but never the
/// generated plan — the distinction the sweep engine's plan-memoization
/// key ([`crate::opt::sweep`]) relies on.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Max/initial JVM heap size of the client (control program), bytes.
    /// Paper cluster (§2): 2048 MB; drives the local memory budget.
    pub cp_heap_bytes: f64,
    /// Max/initial JVM heap size of each map task, bytes. Paper: 2048 MB;
    /// drives the remote budget that gates mapmm broadcasts (§2).
    pub map_heap_bytes: f64,
    /// Max/initial JVM heap size of each reduce task, bytes. Paper: 2048 MB.
    pub reduce_heap_bytes: f64,
    /// Degree of parallelism of the local control program (`k_l`, §3.3).
    /// Paper: 24 vcores on the head node (Figure 1 header).
    pub k_local: usize,
    /// Available map slots in the cluster (`k_m`, §3.3). Paper: 144.
    pub k_map: usize,
    /// Available reduce slots in the cluster (`k_r`, §3.3). Paper: 72.
    pub k_reduce: usize,
    /// HDFS block size in bytes (also the input-split size used for the
    /// `nmap = ⌈M'(X)/block⌉` task count, §3.3). Paper: 128 MB.
    pub hdfs_block_bytes: f64,
    /// Number of worker nodes (used by YARN-style resource correction,
    /// §3.1). Paper: 6 workers (1+6 cluster).
    pub nodes: usize,
    /// Per-node virtual cores (YARN correction input). Paper: 24.
    pub vcores_per_node: usize,
    /// Per-node memory available to YARN containers, bytes. Paper: 96 GB.
    pub yarn_mem_per_node: f64,
    /// Processor clock in Hz used to convert FLOPs to seconds (paper §3.3:
    /// "assuming 1 FLOP per cycle"). Calibrated to 2.15 GHz, which
    /// reproduces the paper's Figure 4/5 compute times exactly (see
    /// DESIGN.md §Constants-calibration).
    pub clock_hz: f64,
    /// Number of Spark executors available to the application (the paper's
    /// abstract names "MapReduce (MR) or similar frameworks like Spark" as
    /// the distributed backends; this is the Spark half). Default: one
    /// executor per worker node.
    pub spark_executors: usize,
    /// Cores per Spark executor (task slots). Default: the node vcores, so
    /// total Spark parallelism matches the MR map-slot count and backend
    /// comparisons isolate latency/shuffle differences, not raw slots.
    pub spark_executor_cores: usize,
    /// Spark executor JVM heap, bytes. Executors are long-lived and fat
    /// (one per node) rather than per-task 2 GB containers, so broadcast
    /// feasibility (`mapmm` vs `cpmm`) is decided against this budget —
    /// the "physical selection driven by executor memory" axis.
    pub spark_executor_mem_bytes: f64,
}

impl ClusterConfig {
    /// The paper's 1+6-node cluster (§2): 2 GB heaps, 128 MB HDFS blocks,
    /// 24 local vcores, 144 map / 72 reduce slots.
    pub fn paper_cluster() -> Self {
        ClusterConfig {
            cp_heap_bytes: 2048.0 * MB,
            map_heap_bytes: 2048.0 * MB,
            reduce_heap_bytes: 2048.0 * MB,
            k_local: 24,
            k_map: 144,
            k_reduce: 72,
            hdfs_block_bytes: 128.0 * MB,
            nodes: 6,
            vcores_per_node: 24,
            yarn_mem_per_node: 96.0 * 1024.0 * MB,
            clock_hz: 2.15e9,
            spark_executors: 6,
            spark_executor_cores: 24,
            spark_executor_mem_bytes: 20.0 * 1024.0 * MB,
        }
    }

    /// A single-node "local" configuration sized for this machine; used by
    /// the executable scenarios and the cost-accuracy experiment.
    pub fn local(threads: usize, heap_bytes: f64) -> Self {
        ClusterConfig {
            cp_heap_bytes: heap_bytes,
            map_heap_bytes: heap_bytes / 4.0,
            reduce_heap_bytes: heap_bytes / 4.0,
            k_local: threads,
            k_map: threads,
            // floored at 1: a single-threaded local config must still
            // validate (every api:: compile entry now rejects zero slots)
            k_reduce: (threads / 2).max(1),
            hdfs_block_bytes: 32.0 * MB,
            nodes: 1,
            vcores_per_node: threads,
            yarn_mem_per_node: heap_bytes * 2.0,
            clock_hz: 2.4e9,
            spark_executors: 1,
            spark_executor_cores: threads,
            spark_executor_mem_bytes: heap_bytes,
        }
    }

    /// Reject configurations the cost model cannot price: a zero or
    /// non-finite heap poisons every memory-budget ratio with NaN (the
    /// historical `spark_executor_mem / cp_heap` division), `k_local == 0`
    /// turns the parfor weight `⌈N̂/k_l⌉` into `inf`, and zero node/slot
    /// counts break the §3.3 parallelism corrections. Called by every
    /// optimizer/sweep entry point ([`crate::opt`]) before compiling, so a
    /// degenerate configuration becomes a diagnostic instead of NaN-ranked
    /// results or a panic.
    pub fn validate(&self) -> Result<(), String> {
        let pos = |name: &str, v: f64| {
            if v.is_finite() && v > 0.0 {
                Ok(())
            } else {
                Err(format!("invalid ClusterConfig: {name} must be finite and > 0, got {v}"))
            }
        };
        pos("cp_heap_bytes", self.cp_heap_bytes)?;
        pos("map_heap_bytes", self.map_heap_bytes)?;
        pos("reduce_heap_bytes", self.reduce_heap_bytes)?;
        pos("spark_executor_mem_bytes", self.spark_executor_mem_bytes)?;
        pos("hdfs_block_bytes", self.hdfs_block_bytes)?;
        pos("yarn_mem_per_node", self.yarn_mem_per_node)?;
        pos("clock_hz", self.clock_hz)?;
        let nonzero = |name: &str, v: usize| {
            if v > 0 {
                Ok(())
            } else {
                Err(format!("invalid ClusterConfig: {name} must be >= 1, got 0"))
            }
        };
        nonzero("k_local", self.k_local)?;
        nonzero("k_map", self.k_map)?;
        nonzero("k_reduce", self.k_reduce)?;
        nonzero("nodes", self.nodes)?;
        nonzero("vcores_per_node", self.vcores_per_node)?;
        nonzero("spark_executors", self.spark_executors)?;
        nonzero("spark_executor_cores", self.spark_executor_cores)?;
        Ok(())
    }

    /// Grid axis: set the client *and* per-task heaps to `mb` megabytes
    /// (the resource optimizer's joint heap axis — plan shape follows the
    /// §2 memory budgets derived from these).
    pub fn with_heap_mb(mut self, mb: f64) -> Self {
        self.cp_heap_bytes = mb * MB;
        self.map_heap_bytes = mb * MB;
        self.reduce_heap_bytes = mb * MB;
        self
    }

    /// Grid axis: set the Spark executor heap to `mb` megabytes (drives
    /// broadcast feasibility — the `mapmm` vs `cpmm` flip — on the Spark
    /// backend; cost-/shape-neutral for CP and MR plans).
    pub fn with_executor_mem_mb(mut self, mb: f64) -> Self {
        self.spark_executor_mem_bytes = mb * MB;
        self
    }

    /// Grid axis: scale the cluster to `nodes` worker nodes, keeping the
    /// per-node geometry: map/reduce slots and Spark executors scale
    /// proportionally from the current node count. Cost-only — node
    /// counts never change plan shape (see the sweep plan signature).
    pub fn with_nodes(mut self, nodes: usize) -> Self {
        let nodes = nodes.max(1);
        let scale = nodes as f64 / self.nodes.max(1) as f64;
        self.k_map = ((self.k_map as f64 * scale).round() as usize).max(1);
        self.k_reduce = ((self.k_reduce as f64 * scale).round() as usize).max(1);
        self.spark_executors = ((self.spark_executors as f64 * scale).round() as usize).max(1);
        self.nodes = nodes;
        self
    }

    /// Grid axis: set the control program's degree of parallelism `k_l`
    /// (the §3.3 parfor divisor). Cost-only, never changes plan shape.
    pub fn with_k_local(mut self, k_local: usize) -> Self {
        self.k_local = k_local.max(1);
        self
    }

    /// Total Spark task slots: executors × cores per executor.
    pub fn k_spark(&self) -> usize {
        (self.spark_executors * self.spark_executor_cores).max(1)
    }

    /// YARN-style correction of map parallelism (§3.1): the effective map
    /// slots are limited by both vcores and container memory.
    pub fn effective_k_map(&self) -> usize {
        let by_vcores = self.nodes * self.vcores_per_node;
        let by_mem = ((self.yarn_mem_per_node / self.map_heap_bytes) as usize).max(1) * self.nodes;
        self.k_map.min(by_vcores).min(by_mem).max(1)
    }

    /// YARN-style correction of reduce parallelism.
    pub fn effective_k_reduce(&self) -> usize {
        let by_vcores = self.nodes * self.vcores_per_node;
        let by_mem =
            ((self.yarn_mem_per_node / self.reduce_heap_bytes) as usize).max(1) * self.nodes;
        self.k_reduce.min(by_vcores).min(by_mem).max(1)
    }
}

pub const KB: f64 = 1024.0;
pub const MB: f64 = 1024.0 * 1024.0;
pub const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Compiler/system configuration (SystemML defaults from §2).
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    /// Matrix block size for the binary-block format (rows and cols).
    /// Default 1000 (SystemML's 1000×1000 blocks, §2); bounds map-side
    /// tsmm feasibility (`ncol ≤ blocksize`).
    pub blocksize: i64,
    /// Fraction of heap available as the optimizer memory budget.
    /// Default 0.70, yielding the paper's 1434 MB budgets (Figure 1).
    pub mem_budget_ratio: f64,
    /// Default number of reducers per MR job. Default 12 = 2× worker
    /// nodes (Figure 3 `num reducers = 12`).
    pub num_reducers: usize,
    /// Replication factor for MR job outputs. Default 1 (Figure 3).
    pub replication: usize,
    /// Sparsity threshold below which matrices are stored sparse in
    /// memory (MatrixBlock rule, §3.1). Default 0.4.
    pub sparse_threshold: f64,
    /// Assumed iterations `N̂` for loops with unknown trip count (§3.5).
    /// Default 10.
    pub unknown_iterations: f64,
    /// Partition size for partitioned broadcasts, bytes. Default 32 MB
    /// (§2 — `_mVar3` in Figure 3 is a partitioned broadcast of y).
    pub partition_bytes: f64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            blocksize: 1000,
            mem_budget_ratio: 0.70,
            num_reducers: 12,
            replication: 1,
            sparse_threshold: 0.4,
            unknown_iterations: 10.0,
            partition_bytes: 32.0 * MB,
        }
    }
}

impl SystemConfig {
    /// Local (CP) memory budget in bytes: ratio * client heap.
    pub fn cp_budget(&self, cc: &ClusterConfig) -> f64 {
        self.mem_budget_ratio * cc.cp_heap_bytes
    }

    /// Remote map-task memory budget in bytes.
    pub fn map_budget(&self, cc: &ClusterConfig) -> f64 {
        self.mem_budget_ratio * cc.map_heap_bytes
    }

    /// Remote reduce-task memory budget in bytes.
    pub fn reduce_budget(&self, cc: &ClusterConfig) -> f64 {
        self.mem_budget_ratio * cc.reduce_heap_bytes
    }

    /// Spark broadcast budget in bytes: ratio × executor heap. Drives the
    /// `mapmm`-broadcast vs `cpmm`-shuffle physical selection on the Spark
    /// backend — fat executors admit broadcasts the 2 GB MR map containers
    /// reject (e.g. the XL3 scenario's 1.6 GB y vector).
    pub fn spark_broadcast_budget(&self, cc: &ClusterConfig) -> f64 {
        self.mem_budget_ratio * cc.spark_executor_mem_bytes
    }
}

/// White-box cost-model constants (§3.3). IO bandwidths are per-thread;
/// latencies are per-job/per-task; FLOP correction factors are per-op.
/// Defaults are calibrated against the paper's Figures 4 and 5 (see
/// DESIGN.md §Constants-calibration for the derivations).
#[derive(Clone, Debug, PartialEq)]
pub struct CostConstants {
    /// Single-threaded HDFS read bandwidth for binary-block format, B/s.
    /// Default 150 MB/s (reproduces Figure 4's 0.51 s read of the 80 MB X).
    pub hdfs_read_binaryblock: f64,
    /// Single-threaded HDFS read bandwidth for text formats, B/s.
    /// Default 75 MB/s (text parsing halves the effective rate).
    pub hdfs_read_text: f64,
    /// Single-threaded HDFS write bandwidth for binary-block, B/s.
    /// Default 120 MB/s.
    pub hdfs_write_binaryblock: f64,
    /// Single-threaded HDFS write bandwidth for text formats, B/s.
    /// Default 60 MB/s.
    pub hdfs_write_text: f64,
    /// Local-disk read bandwidth (scratch space / buffer-pool evictions),
    /// B/s. Default 200 MB/s.
    pub local_read: f64,
    /// Local-disk write bandwidth, B/s. Default 160 MB/s.
    pub local_write: f64,
    /// Distributed-cache read bandwidth per task, B/s. Default 215 MB/s
    /// (calibrated against Figure 5's dcread = 12.6 s).
    pub dcache_read: f64,
    /// Shuffle end-to-end bandwidth (map write + transfer + reduce
    /// merge), B/s. Default 96 MB/s (Figure 5 shuffle = 19.7 s).
    pub shuffle_bw: f64,
    /// Main-memory bandwidth (per thread) used for memory-bound ops,
    /// B/s. Default 2.5 GB/s.
    pub mem_bw: f64,
    /// MR job submission latency, seconds. Default 20 s (Hadoop job
    /// startup; dominates tiny jobs, §3.3).
    pub job_latency: f64,
    /// Per-task startup latency, seconds. Default 1.5 s (Figure 5:
    /// latency = 144.5 s for 5967 map tasks at dop 72·0.5·... ).
    pub task_latency: f64,
    /// Fixed cost of bookkeeping instructions (createvar etc.), seconds.
    /// Default 4.7e-9 s (Figure 4 prints `4.7E-9s` per createvar).
    pub bookkeeping: f64,
    /// Scale factor applied to the parallelism minimum when computing the
    /// effective degree of parallelism of MR phases (§3.3 "scaled minimum";
    /// accounts for stragglers and slot contention).
    pub dop_scale: f64,
    /// Spark job submission latency, seconds. Default 1.0 s: the driver
    /// schedules jobs against long-lived executors, so there is no per-job
    /// JVM/container startup — the dominant reason Spark wins on
    /// multi-iteration loops (Kaoudi et al. 2017 observe the same flip).
    pub spark_job_latency: f64,
    /// Per-stage scheduling/barrier latency, seconds. Default 0.3 s
    /// (DAGScheduler stage submission + executor wake-up).
    pub spark_stage_latency: f64,
    /// Per-task launch latency, seconds. Default 0.05 s: tasks are
    /// threads in a running executor, ~30× cheaper than an MR task JVM.
    pub spark_task_latency: f64,
    /// Shuffle write bandwidth per task (sorted spill to local disk),
    /// B/s. Default 200 MB/s.
    pub spark_shuffle_write: f64,
    /// Shuffle read bandwidth per task (network fetch + merge), B/s.
    /// Default 150 MB/s. Spark shuffles in two passes (write, read) vs
    /// MR's three (map write, transfer, reduce merge-sort).
    pub spark_shuffle_read: f64,
    /// Torrent-broadcast bandwidth, B/s. Default 300 MB/s: executors
    /// fetch blocks from peers in parallel, so one broadcast costs
    /// ~size/bw once — unlike the MR distributed cache, which every map
    /// task re-reads.
    pub spark_broadcast_bw: f64,
    /// Dimensionless per-op FLOP efficiency: every compute term divides by
    /// `clock_hz * flop_efficiency`. Default 1.0 (the paper folds kernel
    /// efficiency into its 2.15 GHz effective clock); online calibration
    /// ([`crate::feedback`]) fits this from measured-vs-predicted block
    /// times instead of mutating the cluster's clock rate.
    pub flop_efficiency: f64,
}

impl Default for CostConstants {
    fn default() -> Self {
        CostConstants {
            hdfs_read_binaryblock: 150.0 * MB,
            hdfs_read_text: 75.0 * MB,
            hdfs_write_binaryblock: 120.0 * MB,
            hdfs_write_text: 60.0 * MB,
            local_read: 200.0 * MB,
            local_write: 160.0 * MB,
            dcache_read: 215.0 * MB,
            shuffle_bw: 96.0 * MB,
            mem_bw: 2.5 * GB,
            job_latency: 20.0,
            task_latency: 1.5,
            bookkeeping: 4.7e-9,
            dop_scale: 0.5,
            spark_job_latency: 1.0,
            spark_stage_latency: 0.3,
            spark_task_latency: 0.05,
            spark_shuffle_write: 200.0 * MB,
            spark_shuffle_read: 150.0 * MB,
            spark_broadcast_bw: 300.0 * MB,
            flop_efficiency: 1.0,
        }
    }
}

impl CostConstants {
    /// Reject constants the model cannot divide by: a zero or non-finite
    /// bandwidth (e.g. a disk bandwidth of 0 B/s) turns every IO term
    /// into `inf`/NaN, which then poisons cost ranking. Latencies must be
    /// finite and non-negative. Called alongside
    /// [`ClusterConfig::validate`] at the optimizer/sweep entry points.
    pub fn validate(&self) -> Result<(), String> {
        let bw = |name: &str, v: f64| {
            if v.is_finite() && v > 0.0 {
                Ok(())
            } else {
                Err(format!("invalid CostConstants: bandwidth {name} must be finite and > 0, got {v}"))
            }
        };
        bw("hdfs_read_binaryblock", self.hdfs_read_binaryblock)?;
        bw("hdfs_read_text", self.hdfs_read_text)?;
        bw("hdfs_write_binaryblock", self.hdfs_write_binaryblock)?;
        bw("hdfs_write_text", self.hdfs_write_text)?;
        bw("local_read", self.local_read)?;
        bw("local_write", self.local_write)?;
        bw("dcache_read", self.dcache_read)?;
        bw("shuffle_bw", self.shuffle_bw)?;
        bw("mem_bw", self.mem_bw)?;
        bw("spark_shuffle_write", self.spark_shuffle_write)?;
        bw("spark_shuffle_read", self.spark_shuffle_read)?;
        bw("spark_broadcast_bw", self.spark_broadcast_bw)?;
        bw("dop_scale", self.dop_scale)?;
        bw("flop_efficiency", self.flop_efficiency)?;
        let lat = |name: &str, v: f64| {
            if v.is_finite() && v >= 0.0 {
                Ok(())
            } else {
                Err(format!("invalid CostConstants: latency {name} must be finite and >= 0, got {v}"))
            }
        };
        lat("job_latency", self.job_latency)?;
        lat("task_latency", self.task_latency)?;
        lat("bookkeeping", self.bookkeeping)?;
        lat("spark_job_latency", self.spark_job_latency)?;
        lat("spark_stage_latency", self.spark_stage_latency)?;
        lat("spark_task_latency", self.spark_task_latency)?;
        Ok(())
    }
}

/// Failure model for distributed (MR/Spark) task execution.
///
/// The paper's Eq. 1 prices *expected* execution time, but its expectation
/// ignores the cluster pathologies that dominate long-running jobs: task
/// failures with retry/backoff, stragglers, and speculative re-execution.
/// A `FaultProfile` makes those a first-class costed dimension — the
/// deterministic simulator ([`crate::mr`]) injects faults from it, and the
/// cost model ([`crate::cost`]) prices the same expectation analytically
/// (geometric retries, backoff latency, straggler tail). The default
/// profile is [`FaultProfile::none`], under which both injection and
/// costing are exact identities: every cost, fingerprint, and golden
/// output is bitwise-identical to a build without the fault layer.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultProfile {
    /// Per-attempt failure probability of an MR task, in `[0, 1)`.
    /// A failed attempt is re-run from scratch after backoff (Hadoop's
    /// `mapreduce.map.maxattempts` retry semantics).
    pub mr_fail_p: f64,
    /// Per-attempt failure probability of a Spark task, in `[0, 1)`.
    /// Spark re-schedules failed tasks within the running executors, so
    /// retries skip the container-startup latency but still redo the work.
    pub spark_fail_p: f64,
    /// Fraction of tasks that straggle, in `[0, 1]` (the LATE-scheduler
    /// observation: a small tail of tasks runs far slower than the median).
    pub straggler_frac: f64,
    /// Slowdown factor of a straggling task relative to the median task,
    /// `>= 1`. A value of 1 means stragglers are indistinguishable.
    pub straggler_slowdown: f64,
    /// Maximum attempts per task (first run + retries), `>= 1`. A task
    /// that fails `max_attempts` times fails the job; the cost model
    /// truncates the retry expectation at this bound.
    pub max_attempts: usize,
    /// Base of the exponential retry backoff, seconds: attempt `a`
    /// (1-indexed retry) waits `backoff_base * 2^(a-1)` before re-running.
    /// Must be finite and `>= 0`.
    pub backoff_base: f64,
    /// Speculative execution toggle: when set, a backup copy of each
    /// straggling task is launched and the earlier finisher wins, capping
    /// the effective straggler slowdown (at the cost of duplicate work).
    pub speculative: bool,
}

impl FaultProfile {
    /// The identity profile: no failures, no stragglers, one attempt.
    /// Under this profile fault-aware costing and injection are exact
    /// no-ops, bitwise.
    pub fn none() -> Self {
        FaultProfile {
            mr_fail_p: 0.0,
            spark_fail_p: 0.0,
            straggler_frac: 0.0,
            straggler_slowdown: 1.0,
            max_attempts: 1,
            backoff_base: 0.0,
            speculative: false,
        }
    }

    /// The bundled chaos profile used by `repro chaos` and the CI chaos
    /// smoke: a lossy cluster where MR tasks fail 8% of attempts, Spark
    /// tasks 18%, a tenth of all tasks straggle at 4x, and tasks retry up
    /// to 4 times under a 0.5 s exponential backoff. Retry-heavy
    /// distributed plans pay enough expected latency here that the
    /// backend argmin of the bundled scenario flips to CP.
    pub fn chaos() -> Self {
        FaultProfile {
            mr_fail_p: 0.08,
            spark_fail_p: 0.18,
            straggler_frac: 0.10,
            straggler_slowdown: 4.0,
            max_attempts: 4,
            backoff_base: 0.5,
            speculative: false,
        }
    }

    /// True when this profile is the identity ([`FaultProfile::none`]):
    /// costing must then skip the fault terms entirely so results stay
    /// bitwise-identical to the fault-unaware model, and fingerprints
    /// must not include the fault knob group (pre-existing cost-cache
    /// snapshots keep replaying).
    pub fn is_none(&self) -> bool {
        self == &FaultProfile::none()
    }

    /// Reject profiles the model cannot price: probabilities outside
    /// `[0, 1)` make the geometric retry expectation `1/(1-p)` divide by
    /// zero or go negative, a slowdown below 1 would *reward* stragglers,
    /// and zero attempts means no task ever runs. Called alongside
    /// [`ClusterConfig::validate`] at optimizer/sweep entry points.
    pub fn validate(&self) -> Result<(), String> {
        let prob = |name: &str, v: f64| {
            if v.is_finite() && (0.0..1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("invalid FaultProfile: {name} must be in [0, 1), got {v}"))
            }
        };
        prob("mr_fail_p", self.mr_fail_p)?;
        prob("spark_fail_p", self.spark_fail_p)?;
        if !(self.straggler_frac.is_finite() && (0.0..=1.0).contains(&self.straggler_frac)) {
            return Err(format!(
                "invalid FaultProfile: straggler_frac must be in [0, 1], got {}",
                self.straggler_frac
            ));
        }
        if !(self.straggler_slowdown.is_finite() && self.straggler_slowdown >= 1.0) {
            return Err(format!(
                "invalid FaultProfile: straggler_slowdown must be finite and >= 1, got {}",
                self.straggler_slowdown
            ));
        }
        if self.max_attempts == 0 {
            return Err("invalid FaultProfile: max_attempts must be >= 1, got 0".to_string());
        }
        if !(self.backoff_base.is_finite() && self.backoff_base >= 0.0) {
            return Err(format!(
                "invalid FaultProfile: backoff_base must be finite and >= 0, got {}",
                self.backoff_base
            ));
        }
        Ok(())
    }

    /// Parse a `--fault-profile` CLI spec: `none`, `chaos`, or a
    /// comma-separated `key=value` list applied on top of `none` (a
    /// leading profile name seeds the base, e.g.
    /// `chaos,spark=0.3,attempts=6`). Keys: `mr`, `spark`, `frac`,
    /// `slow`, `attempts`, `backoff`, `speculative` (bool). The result is
    /// validated before it is returned.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut fp = FaultProfile::none();
        for (i, tok) in spec.split(',').enumerate() {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            match tok {
                "none" if i == 0 => fp = FaultProfile::none(),
                "chaos" if i == 0 => fp = FaultProfile::chaos(),
                _ => {
                    let (key, val) = tok.split_once('=').ok_or_else(|| {
                        format!("invalid fault-profile token {tok:?}: expected key=value, 'none' or 'chaos'")
                    })?;
                    let num = |v: &str| {
                        v.parse::<f64>()
                            .map_err(|_| format!("invalid fault-profile value for {key}: {v:?}"))
                    };
                    match key {
                        "mr" | "mr_fail_p" => fp.mr_fail_p = num(val)?,
                        "spark" | "spark_fail_p" => fp.spark_fail_p = num(val)?,
                        "frac" | "straggler_frac" => fp.straggler_frac = num(val)?,
                        "slow" | "straggler_slowdown" => fp.straggler_slowdown = num(val)?,
                        "backoff" | "backoff_base" => fp.backoff_base = num(val)?,
                        "attempts" | "max_attempts" => {
                            fp.max_attempts = val.parse::<usize>().map_err(|_| {
                                format!("invalid fault-profile value for attempts: {val:?}")
                            })?
                        }
                        "speculative" | "spec" => {
                            fp.speculative = match val {
                                "true" | "on" | "1" => true,
                                "false" | "off" | "0" => false,
                                _ => {
                                    return Err(format!(
                                        "invalid fault-profile value for speculative: {val:?}"
                                    ))
                                }
                            }
                        }
                        _ => {
                            return Err(format!(
                                "unknown fault-profile key {key:?} (known: mr, spark, frac, slow, attempts, backoff, speculative)"
                            ))
                        }
                    }
                }
            }
        }
        fp.validate()?;
        Ok(fp)
    }

    /// Expected number of attempts per task at per-attempt failure
    /// probability `p`, truncated at [`FaultProfile::max_attempts`]:
    /// `E[A] = (1 - p^m) / (1 - p)` — the partial-sum form of the
    /// geometric `1/(1-p)`, which it approaches as `m → ∞`. Exactly 1.0
    /// when `p == 0`.
    pub fn expected_attempts(&self, p: f64) -> f64 {
        if p <= 0.0 {
            return 1.0;
        }
        (1.0 - p.powi(self.max_attempts.max(1) as i32)) / (1.0 - p)
    }

    /// Expected exponential-backoff wait per task at failure probability
    /// `p`, seconds: retry `a` happens with probability `p^a` and waits
    /// `backoff_base * 2^(a-1)`, summed over the `max_attempts - 1`
    /// possible retries. Exactly 0.0 when `p == 0` or the base is 0.
    pub fn expected_backoff(&self, p: f64) -> f64 {
        if p <= 0.0 || self.backoff_base <= 0.0 {
            return 0.0;
        }
        let mut sum = 0.0;
        let mut pa = 1.0;
        for a in 1..self.max_attempts.max(1) {
            pa *= p;
            sum += pa * self.backoff_base * 2f64.powi(a as i32 - 1);
        }
        sum
    }

    /// Straggler tail multiplier (`>= 1`) applied to the last wave of a
    /// task phase: `1 + frac * (s_eff - 1)` where `s_eff` is the
    /// straggler slowdown, capped at 2 when speculative execution is on
    /// (the backup copy bounds the observable slowdown at roughly one
    /// extra task length). Exactly 1.0 when no tasks straggle.
    pub fn straggler_tail(&self) -> f64 {
        let s_eff = if self.speculative {
            self.straggler_slowdown.min(2.0)
        } else {
            self.straggler_slowdown
        };
        1.0 + self.straggler_frac * (s_eff - 1.0).max(0.0)
    }
}

impl Default for FaultProfile {
    fn default() -> Self {
        FaultProfile::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_budget_matches_figure1_header() {
        // Figure 1: "Memory Budget local/remote = 1434MB/1434MB".
        let cc = ClusterConfig::paper_cluster();
        let sc = SystemConfig::default();
        let budget_mb = sc.cp_budget(&cc) / MB;
        assert_eq!(budget_mb.round() as i64, 1434);
        assert_eq!((sc.map_budget(&cc) / MB).round() as i64, 1434);
    }

    #[test]
    fn paper_cluster_parallelism_matches_figure1_header() {
        // Figure 1: "Degree of Parallelism (vcores) local/remote = 24/144/72".
        let cc = ClusterConfig::paper_cluster();
        assert_eq!(cc.k_local, 24);
        assert_eq!(cc.effective_k_map(), 144);
        assert_eq!(cc.effective_k_reduce(), 72);
    }

    #[test]
    fn yarn_memory_correction_limits_slots() {
        let mut cc = ClusterConfig::paper_cluster();
        cc.yarn_mem_per_node = 4.0 * 1024.0 * MB; // only 2 containers/node
        assert_eq!(cc.effective_k_map(), 12);
    }

    #[test]
    fn default_system_config_matches_paper() {
        let sc = SystemConfig::default();
        assert_eq!(sc.blocksize, 1000);
        assert_eq!(sc.num_reducers, 12);
        assert!((sc.mem_budget_ratio - 0.70).abs() < 1e-12);
        assert_eq!(sc.partition_bytes, 32.0 * MB);
    }

    #[test]
    fn local_cluster_is_single_node() {
        let cc = ClusterConfig::local(8, 4.0 * GB);
        assert_eq!(cc.nodes, 1);
        assert!(cc.effective_k_map() <= 8);
        assert_eq!(cc.k_spark(), 8);
    }

    #[test]
    fn spark_parallelism_matches_mr_slots_on_paper_cluster() {
        // Backend comparisons isolate latency/shuffle terms: Spark's task
        // slots equal the MR map slots on the default cluster.
        let cc = ClusterConfig::paper_cluster();
        assert_eq!(cc.k_spark(), cc.effective_k_map());
    }

    #[test]
    fn spark_broadcast_budget_exceeds_map_budget() {
        // Fat executors (20 GB) vs 2 GB map containers: the Spark backend
        // admits broadcasts MR rejects (mapmm-vs-cpmm flip, XL3).
        let cc = ClusterConfig::paper_cluster();
        let sc = SystemConfig::default();
        assert!(sc.spark_broadcast_budget(&cc) > sc.map_budget(&cc));
        assert_eq!((sc.spark_broadcast_budget(&cc) / MB).round() as i64, 14336);
    }

    #[test]
    fn spark_latencies_far_below_mr() {
        let k = CostConstants::default();
        assert!(k.spark_job_latency * 10.0 < k.job_latency);
        assert!(k.spark_task_latency * 10.0 < k.task_latency);
    }

    #[test]
    fn default_configs_validate() {
        ClusterConfig::paper_cluster().validate().unwrap();
        ClusterConfig::local(8, 4.0 * GB).validate().unwrap();
        CostConstants::default().validate().unwrap();
    }

    #[test]
    fn zero_heap_rejected_with_diagnostic() {
        let mut cc = ClusterConfig::paper_cluster();
        cc.cp_heap_bytes = 0.0;
        let err = cc.validate().unwrap_err();
        assert!(err.contains("cp_heap_bytes"), "{err}");
    }

    #[test]
    fn zero_k_local_rejected() {
        let mut cc = ClusterConfig::paper_cluster();
        cc.k_local = 0;
        let err = cc.validate().unwrap_err();
        assert!(err.contains("k_local"), "{err}");
    }

    #[test]
    fn nan_and_negative_fields_rejected() {
        let mut cc = ClusterConfig::paper_cluster();
        cc.map_heap_bytes = f64::NAN;
        assert!(cc.validate().is_err());
        let mut cc = ClusterConfig::paper_cluster();
        cc.clock_hz = -1.0;
        assert!(cc.validate().is_err());
    }

    #[test]
    fn zero_disk_bandwidth_rejected() {
        let k = CostConstants { hdfs_read_binaryblock: 0.0, ..CostConstants::default() };
        let err = k.validate().unwrap_err();
        assert!(err.contains("hdfs_read_binaryblock"), "{err}");
    }

    #[test]
    fn axis_helpers_apply_and_scale() {
        let cc = ClusterConfig::paper_cluster()
            .with_heap_mb(512.0)
            .with_executor_mem_mb(4096.0)
            .with_nodes(12)
            .with_k_local(8);
        assert_eq!(cc.cp_heap_bytes, 512.0 * MB);
        assert_eq!(cc.map_heap_bytes, 512.0 * MB);
        assert_eq!(cc.reduce_heap_bytes, 512.0 * MB);
        assert_eq!(cc.spark_executor_mem_bytes, 4096.0 * MB);
        // doubling 6 -> 12 nodes doubles the per-node-proportional slots
        assert_eq!(cc.nodes, 12);
        assert_eq!(cc.k_map, 288);
        assert_eq!(cc.k_reduce, 144);
        assert_eq!(cc.spark_executors, 12);
        assert_eq!(cc.k_local, 8);
        cc.validate().unwrap();
    }

    #[test]
    fn fault_profile_none_is_identity() {
        let fp = FaultProfile::none();
        fp.validate().unwrap();
        assert!(fp.is_none());
        assert_eq!(fp, FaultProfile::default());
        assert_eq!(fp.expected_attempts(fp.mr_fail_p), 1.0);
        assert_eq!(fp.expected_backoff(fp.mr_fail_p), 0.0);
        assert_eq!(fp.straggler_tail(), 1.0);
    }

    #[test]
    fn fault_profile_chaos_validates_and_is_not_none() {
        let fp = FaultProfile::chaos();
        fp.validate().unwrap();
        assert!(!fp.is_none());
        assert!(fp.expected_attempts(fp.spark_fail_p) > 1.0);
        assert!(fp.expected_backoff(fp.spark_fail_p) > 0.0);
        assert!(fp.straggler_tail() > 1.0);
    }

    #[test]
    fn fault_profile_rejects_degenerate_values() {
        let mut fp = FaultProfile::chaos();
        fp.mr_fail_p = 1.0; // 1/(1-p) would divide by zero
        assert!(fp.validate().unwrap_err().contains("mr_fail_p"));
        let mut fp = FaultProfile::chaos();
        fp.spark_fail_p = -0.1;
        assert!(fp.validate().unwrap_err().contains("spark_fail_p"));
        let mut fp = FaultProfile::chaos();
        fp.straggler_slowdown = 0.5; // would reward stragglers
        assert!(fp.validate().unwrap_err().contains("straggler_slowdown"));
        let mut fp = FaultProfile::chaos();
        fp.max_attempts = 0;
        assert!(fp.validate().unwrap_err().contains("max_attempts"));
        let mut fp = FaultProfile::chaos();
        fp.backoff_base = f64::NAN;
        assert!(fp.validate().unwrap_err().contains("backoff_base"));
    }

    #[test]
    fn fault_profile_parse_names_and_overrides() {
        assert_eq!(FaultProfile::parse("none").unwrap(), FaultProfile::none());
        assert_eq!(FaultProfile::parse("chaos").unwrap(), FaultProfile::chaos());
        let fp = FaultProfile::parse("chaos,spark=0.3,attempts=6,speculative=on").unwrap();
        assert_eq!(fp.spark_fail_p, 0.3);
        assert_eq!(fp.max_attempts, 6);
        assert!(fp.speculative);
        assert_eq!(fp.mr_fail_p, FaultProfile::chaos().mr_fail_p);
        let fp = FaultProfile::parse("mr=0.05,slow=3.0,frac=0.2,backoff=0.25").unwrap();
        assert_eq!(fp.mr_fail_p, 0.05);
        assert_eq!(fp.straggler_slowdown, 3.0);
        assert_eq!(fp.straggler_frac, 0.2);
        assert_eq!(fp.backoff_base, 0.25);
        assert_eq!(fp.spark_fail_p, 0.0);
        assert!(FaultProfile::parse("bogus").is_err());
        assert!(FaultProfile::parse("mr=nope").is_err());
        assert!(FaultProfile::parse("mr=1.5").is_err()); // parse validates
    }

    #[test]
    fn fault_profile_expectation_math() {
        // E[A] truncated geometric: p=0.5, m=4 -> (1 - 0.0625) / 0.5 = 1.875
        let fp = FaultProfile {
            mr_fail_p: 0.5,
            max_attempts: 4,
            backoff_base: 1.0,
            ..FaultProfile::none()
        };
        assert!((fp.expected_attempts(0.5) - 1.875).abs() < 1e-12);
        // Backoff: 0.5*1 + 0.25*2 + 0.125*4 = 1.5
        assert!((fp.expected_backoff(0.5) - 1.5).abs() < 1e-12);
        // Straggler tail: frac=0.1, slow=4 -> 1.3; speculation caps at 2 -> 1.1
        let fp = FaultProfile {
            straggler_frac: 0.1,
            straggler_slowdown: 4.0,
            ..FaultProfile::none()
        };
        assert!((fp.straggler_tail() - 1.3).abs() < 1e-12);
        let fp = FaultProfile { speculative: true, ..fp };
        assert!((fp.straggler_tail() - 1.1).abs() < 1e-12);
    }
}
