//! Cluster, system and cost-model configuration.
//!
//! The paper's cost model `C(P, cc)` is explicitly parameterised by a
//! cluster configuration `cc` (§3, R3). [`ClusterConfig`] captures the
//! paper's 1+6-node Hadoop testbed as its default; [`CostConstants`]
//! collects the white-box model constants (IO bandwidths, latencies, FLOP
//! correction factors) calibrated in DESIGN.md; [`SystemConfig`] holds the
//! compiler-level knobs (block size, memory budget ratio, #reducers).

/// Cluster characteristics `cc` used by the optimizer and the cost model.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Max/initial JVM heap size of the client (control program), bytes.
    pub cp_heap_bytes: f64,
    /// Max/initial JVM heap size of each map task, bytes.
    pub map_heap_bytes: f64,
    /// Max/initial JVM heap size of each reduce task, bytes.
    pub reduce_heap_bytes: f64,
    /// Degree of parallelism of the local control program (`k_l`).
    pub k_local: usize,
    /// Available map slots in the cluster (`k_m`).
    pub k_map: usize,
    /// Available reduce slots in the cluster (`k_r`).
    pub k_reduce: usize,
    /// HDFS block size in bytes (also the input-split size).
    pub hdfs_block_bytes: f64,
    /// Number of worker nodes (used by YARN-style resource correction).
    pub nodes: usize,
    /// Per-node virtual cores (YARN correction input).
    pub vcores_per_node: usize,
    /// Per-node memory available to YARN containers, bytes.
    pub yarn_mem_per_node: f64,
    /// Processor clock in Hz used to convert FLOPs to seconds (paper §3.3:
    /// "assuming 1 FLOP per cycle"). Calibrated to 2.15 GHz, which
    /// reproduces the paper's Figure 4/5 compute times exactly (see
    /// DESIGN.md §Constants-calibration).
    pub clock_hz: f64,
}

impl ClusterConfig {
    /// The paper's 1+6-node cluster (§2): 2 GB heaps, 128 MB HDFS blocks,
    /// 24 local vcores, 144 map / 72 reduce slots.
    pub fn paper_cluster() -> Self {
        ClusterConfig {
            cp_heap_bytes: 2048.0 * MB,
            map_heap_bytes: 2048.0 * MB,
            reduce_heap_bytes: 2048.0 * MB,
            k_local: 24,
            k_map: 144,
            k_reduce: 72,
            hdfs_block_bytes: 128.0 * MB,
            nodes: 6,
            vcores_per_node: 24,
            yarn_mem_per_node: 96.0 * 1024.0 * MB,
            clock_hz: 2.15e9,
        }
    }

    /// A single-node "local" configuration sized for this machine; used by
    /// the executable scenarios and the cost-accuracy experiment.
    pub fn local(threads: usize, heap_bytes: f64) -> Self {
        ClusterConfig {
            cp_heap_bytes: heap_bytes,
            map_heap_bytes: heap_bytes / 4.0,
            reduce_heap_bytes: heap_bytes / 4.0,
            k_local: threads,
            k_map: threads,
            k_reduce: threads / 2,
            hdfs_block_bytes: 32.0 * MB,
            nodes: 1,
            vcores_per_node: threads,
            yarn_mem_per_node: heap_bytes * 2.0,
            clock_hz: 2.4e9,
        }
    }

    /// YARN-style correction of map parallelism (§3.1): the effective map
    /// slots are limited by both vcores and container memory.
    pub fn effective_k_map(&self) -> usize {
        let by_vcores = self.nodes * self.vcores_per_node;
        let by_mem = ((self.yarn_mem_per_node / self.map_heap_bytes) as usize).max(1) * self.nodes;
        self.k_map.min(by_vcores).min(by_mem).max(1)
    }

    /// YARN-style correction of reduce parallelism.
    pub fn effective_k_reduce(&self) -> usize {
        let by_vcores = self.nodes * self.vcores_per_node;
        let by_mem =
            ((self.yarn_mem_per_node / self.reduce_heap_bytes) as usize).max(1) * self.nodes;
        self.k_reduce.min(by_vcores).min(by_mem).max(1)
    }
}

pub const KB: f64 = 1024.0;
pub const MB: f64 = 1024.0 * 1024.0;
pub const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Compiler/system configuration (SystemML defaults from §2).
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    /// Matrix block size for the binary-block format (rows and cols).
    pub blocksize: i64,
    /// Fraction of heap available as the optimizer memory budget (0.70).
    pub mem_budget_ratio: f64,
    /// Default number of reducers (2x number of worker nodes).
    pub num_reducers: usize,
    /// Replication factor for MR job outputs.
    pub replication: usize,
    /// Sparsity threshold below which matrices are stored sparse in memory.
    pub sparse_threshold: f64,
    /// Assumed iterations for loops with unknown trip count (§3.5, `N̂`).
    pub unknown_iterations: f64,
    /// Partition size for partitioned broadcasts (32 MB, §2).
    pub partition_bytes: f64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            blocksize: 1000,
            mem_budget_ratio: 0.70,
            num_reducers: 12,
            replication: 1,
            sparse_threshold: 0.4,
            unknown_iterations: 10.0,
            partition_bytes: 32.0 * MB,
        }
    }
}

impl SystemConfig {
    /// Local (CP) memory budget in bytes: ratio * client heap.
    pub fn cp_budget(&self, cc: &ClusterConfig) -> f64 {
        self.mem_budget_ratio * cc.cp_heap_bytes
    }

    /// Remote map-task memory budget in bytes.
    pub fn map_budget(&self, cc: &ClusterConfig) -> f64 {
        self.mem_budget_ratio * cc.map_heap_bytes
    }

    /// Remote reduce-task memory budget in bytes.
    pub fn reduce_budget(&self, cc: &ClusterConfig) -> f64 {
        self.mem_budget_ratio * cc.reduce_heap_bytes
    }
}

/// White-box cost-model constants (§3.3). IO bandwidths are per-thread;
/// latencies are per-job/per-task; FLOP correction factors are per-op.
/// Defaults are calibrated against the paper's Figures 4 and 5 (see
/// DESIGN.md §Constants-calibration for the derivations).
#[derive(Clone, Debug, PartialEq)]
pub struct CostConstants {
    /// Single-threaded HDFS read bandwidth for binary-block format, B/s.
    pub hdfs_read_binaryblock: f64,
    /// Single-threaded HDFS read bandwidth for text formats, B/s.
    pub hdfs_read_text: f64,
    /// Single-threaded HDFS write bandwidth for binary-block, B/s.
    pub hdfs_write_binaryblock: f64,
    /// Single-threaded HDFS write bandwidth for text formats, B/s.
    pub hdfs_write_text: f64,
    /// Local-disk read bandwidth (scratch space / buffer-pool evictions).
    pub local_read: f64,
    /// Local-disk write bandwidth.
    pub local_write: f64,
    /// Distributed-cache read bandwidth per task, B/s.
    pub dcache_read: f64,
    /// Shuffle end-to-end bandwidth (map write + transfer + reduce merge).
    pub shuffle_bw: f64,
    /// Main-memory bandwidth (per thread) used for memory-bound ops, B/s.
    pub mem_bw: f64,
    /// MR job submission latency, seconds (Hadoop job startup ~20 s).
    pub job_latency: f64,
    /// Per-task startup latency, seconds.
    pub task_latency: f64,
    /// Fixed cost of bookkeeping instructions (createvar etc.), seconds.
    pub bookkeeping: f64,
    /// Scale factor applied to the parallelism minimum when computing the
    /// effective degree of parallelism of MR phases (§3.3 "scaled minimum";
    /// accounts for stragglers and slot contention).
    pub dop_scale: f64,
}

impl Default for CostConstants {
    fn default() -> Self {
        CostConstants {
            hdfs_read_binaryblock: 150.0 * MB,
            hdfs_read_text: 75.0 * MB,
            hdfs_write_binaryblock: 120.0 * MB,
            hdfs_write_text: 60.0 * MB,
            local_read: 200.0 * MB,
            local_write: 160.0 * MB,
            dcache_read: 215.0 * MB,
            shuffle_bw: 96.0 * MB,
            mem_bw: 2.5 * GB,
            job_latency: 20.0,
            task_latency: 1.5,
            bookkeeping: 4.7e-9,
            dop_scale: 0.5,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_budget_matches_figure1_header() {
        // Figure 1: "Memory Budget local/remote = 1434MB/1434MB".
        let cc = ClusterConfig::paper_cluster();
        let sc = SystemConfig::default();
        let budget_mb = sc.cp_budget(&cc) / MB;
        assert_eq!(budget_mb.round() as i64, 1434);
        assert_eq!((sc.map_budget(&cc) / MB).round() as i64, 1434);
    }

    #[test]
    fn paper_cluster_parallelism_matches_figure1_header() {
        // Figure 1: "Degree of Parallelism (vcores) local/remote = 24/144/72".
        let cc = ClusterConfig::paper_cluster();
        assert_eq!(cc.k_local, 24);
        assert_eq!(cc.effective_k_map(), 144);
        assert_eq!(cc.effective_k_reduce(), 72);
    }

    #[test]
    fn yarn_memory_correction_limits_slots() {
        let mut cc = ClusterConfig::paper_cluster();
        cc.yarn_mem_per_node = 4.0 * 1024.0 * MB; // only 2 containers/node
        assert_eq!(cc.effective_k_map(), 12);
    }

    #[test]
    fn default_system_config_matches_paper() {
        let sc = SystemConfig::default();
        assert_eq!(sc.blocksize, 1000);
        assert_eq!(sc.num_reducers, 12);
        assert!((sc.mem_budget_ratio - 0.70).abs() < 1e-12);
        assert_eq!(sc.partition_bytes, 32.0 * MB);
    }

    #[test]
    fn local_cluster_is_single_node() {
        let cc = ClusterConfig::local(8, 4.0 * GB);
        assert_eq!(cc.nodes, 1);
        assert!(cc.effective_k_map() <= 8);
    }
}
