//! Dense row-major matrix.

use crate::util::rng::Rng;

/// Dense row-major f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub values: Vec<f64>,
}

impl DenseMatrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, values: vec![0.0; rows * cols] }
    }

    /// Constant-filled matrix (DML `matrix(v, rows, cols)`).
    pub fn filled(rows: usize, cols: usize, v: f64) -> Self {
        DenseMatrix { rows, cols, values: vec![v; rows * cols] }
    }

    /// From row-major data.
    pub fn from_vec(rows: usize, cols: usize, values: Vec<f64>) -> Self {
        assert_eq!(values.len(), rows * cols, "shape/data mismatch");
        DenseMatrix { rows, cols, values }
    }

    /// Uniform random matrix in [lo, hi) with the given sparsity.
    pub fn rand(rows: usize, cols: usize, lo: f64, hi: f64, sparsity: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut values = vec![0.0; rows * cols];
        for v in values.iter_mut() {
            if sparsity >= 1.0 || rng.chance(sparsity) {
                *v = rng.uniform(lo, hi);
            }
        }
        DenseMatrix { rows, cols, values }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.values[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.values[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.values[r * self.cols + c] = v;
    }

    /// Row slice view.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.values[r * self.cols..(r + 1) * self.cols]
    }

    /// Count non-zero values.
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|v| **v != 0.0).count()
    }

    /// Max absolute elementwise difference to another matrix.
    pub fn max_abs_diff(&self, other: &DenseMatrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.values
            .iter()
            .zip(&other.values)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Frobenius norm.
    pub fn frob(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_filled() {
        let z = DenseMatrix::zeros(3, 4);
        assert_eq!(z.nnz(), 0);
        let f = DenseMatrix::filled(2, 2, 5.0);
        assert_eq!(f.get(1, 1), 5.0);
        assert_eq!(f.nnz(), 4);
    }

    #[test]
    fn eye_diagonal() {
        let i = DenseMatrix::eye(4);
        assert_eq!(i.nnz(), 4);
        assert_eq!(i.get(2, 2), 1.0);
        assert_eq!(i.get(2, 3), 0.0);
    }

    #[test]
    fn rand_respects_bounds_and_sparsity() {
        let m = DenseMatrix::rand(100, 100, -1.0, 1.0, 0.5, 7);
        assert!(m.values.iter().all(|v| (-1.0..1.0).contains(v)));
        let s = m.nnz() as f64 / 10_000.0;
        assert!((s - 0.5).abs() < 0.05, "sparsity={s}");
    }

    #[test]
    fn rand_deterministic() {
        let a = DenseMatrix::rand(10, 10, 0.0, 1.0, 1.0, 42);
        let b = DenseMatrix::rand(10, 10, 0.0, 1.0, 1.0, 42);
        assert_eq!(a, b);
    }

    #[test]
    fn row_access() {
        let m = DenseMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }
}
