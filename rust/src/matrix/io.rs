//! Matrix IO: binary-block and textcell serialization to local files,
//! standing in for HDFS in the executable scenarios. A sibling `.mtd`
//! metadata file carries dimensions/nnz/format, like SystemML's.

use std::fs;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::dense::DenseMatrix;
use super::{Format, MatrixCharacteristics};

/// Simple JSON-ish metadata sidecar (SystemML writes `<file>.mtd`).
pub fn write_mtd(path: &str, mc: &MatrixCharacteristics, format: Format) -> std::io::Result<()> {
    let mtd = format!(
        "{{\"data_type\":\"matrix\",\"value_type\":\"double\",\"rows\":{},\"cols\":{},\"rows_in_block\":{},\"cols_in_block\":{},\"nnz\":{},\"format\":\"{}\"}}\n",
        mc.rows, mc.cols, mc.brows, mc.bcols, mc.nnz, format.name()
    );
    fs::write(format!("{path}.mtd"), mtd)
}

/// Parse the metadata sidecar.
pub fn read_mtd(path: &str) -> std::io::Result<(MatrixCharacteristics, Format)> {
    let text = fs::read_to_string(format!("{path}.mtd"))?;
    let get_i64 = |key: &str| -> i64 {
        text.split(&format!("\"{key}\":"))
            .nth(1)
            .and_then(|s| s.split([',', '}']).next())
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(-1)
    };
    let format = if text.contains("textcell") {
        Format::TextCell
    } else if text.contains("csv") {
        Format::Csv
    } else {
        Format::BinaryBlock
    };
    Ok((
        MatrixCharacteristics {
            rows: get_i64("rows"),
            cols: get_i64("cols"),
            brows: get_i64("rows_in_block"),
            bcols: get_i64("cols_in_block"),
            nnz: get_i64("nnz"),
        },
        format,
    ))
}

/// Write a dense matrix in binary-block format: a little-endian stream of
/// `(block_row, block_col, rows, cols, values...)` records, row-major within
/// each block.
pub fn write_binary_block(
    path: &str,
    m: &DenseMatrix,
    blocksize: usize,
) -> std::io::Result<()> {
    if let Some(parent) = Path::new(path).parent() {
        fs::create_dir_all(parent)?;
    }
    let f = fs::File::create(path)?;
    let mut w = BufWriter::with_capacity(1 << 20, f);
    let rb = (m.rows + blocksize - 1) / blocksize.max(1);
    let cb = (m.cols + blocksize - 1) / blocksize.max(1);
    for bi in 0..rb.max(1) {
        for bj in 0..cb.max(1) {
            let r0 = bi * blocksize;
            let c0 = bj * blocksize;
            let rows = blocksize.min(m.rows - r0);
            let cols = blocksize.min(m.cols - c0);
            w.write_all(&(bi as u32).to_le_bytes())?;
            w.write_all(&(bj as u32).to_le_bytes())?;
            w.write_all(&(rows as u32).to_le_bytes())?;
            w.write_all(&(cols as u32).to_le_bytes())?;
            for r in r0..r0 + rows {
                let row = &m.row(r)[c0..c0 + cols];
                // SAFETY-free serialization: write each f64 LE.
                for v in row {
                    w.write_all(&v.to_le_bytes())?;
                }
            }
        }
    }
    w.flush()?;
    write_mtd(
        path,
        &MatrixCharacteristics::new(m.rows as i64, m.cols as i64, blocksize as i64, m.nnz() as i64),
        Format::BinaryBlock,
    )
}

/// Read a binary-block file written by [`write_binary_block`].
pub fn read_binary_block(path: &str) -> std::io::Result<DenseMatrix> {
    let (mc, _) = read_mtd(path)?;
    let mut m = DenseMatrix::zeros(mc.rows as usize, mc.cols as usize);
    let blocksize = mc.brows as usize;
    let f = fs::File::open(path)?;
    let mut r = BufReader::with_capacity(1 << 20, f);
    let mut hdr = [0u8; 16];
    loop {
        match r.read_exact(&mut hdr) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e),
        }
        let bi = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
        let bj = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
        let rows = u32::from_le_bytes(hdr[8..12].try_into().unwrap()) as usize;
        let cols = u32::from_le_bytes(hdr[12..16].try_into().unwrap()) as usize;
        let mut buf = vec![0u8; rows * cols * 8];
        r.read_exact(&mut buf)?;
        let r0 = bi * blocksize;
        let c0 = bj * blocksize;
        for i in 0..rows {
            for j in 0..cols {
                let o = (i * cols + j) * 8;
                let v = f64::from_le_bytes(buf[o..o + 8].try_into().unwrap());
                m.set(r0 + i, c0 + j, v);
            }
        }
    }
    Ok(m)
}

/// Write textcell format: `row col value` per line, 1-based, nonzeros only.
pub fn write_textcell(path: &str, m: &DenseMatrix) -> std::io::Result<()> {
    if let Some(parent) = Path::new(path).parent() {
        fs::create_dir_all(parent)?;
    }
    let f = fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for r in 0..m.rows {
        for c in 0..m.cols {
            let v = m.get(r, c);
            if v != 0.0 {
                writeln!(w, "{} {} {}", r + 1, c + 1, v)?;
            }
        }
    }
    w.flush()?;
    write_mtd(
        path,
        &MatrixCharacteristics::new(m.rows as i64, m.cols as i64, -1, m.nnz() as i64),
        Format::TextCell,
    )
}

/// Read textcell format (needs the `.mtd` sidecar for dimensions).
pub fn read_textcell(path: &str) -> std::io::Result<DenseMatrix> {
    let (mc, _) = read_mtd(path)?;
    let mut m = DenseMatrix::zeros(mc.rows as usize, mc.cols as usize);
    let f = fs::File::open(path)?;
    for line in BufReader::new(f).lines() {
        let line = line?;
        let mut it = line.split_whitespace();
        let (Some(r), Some(c), Some(v)) = (it.next(), it.next(), it.next()) else { continue };
        let (r, c): (usize, usize) = (r.parse().unwrap_or(1), c.parse().unwrap_or(1));
        m.set(r - 1, c - 1, v.parse().unwrap_or(0.0));
    }
    Ok(m)
}

/// Read any supported format by consulting the metadata sidecar.
pub fn read_matrix(path: &str) -> std::io::Result<DenseMatrix> {
    let (_, format) = read_mtd(path)?;
    match format {
        Format::BinaryBlock => read_binary_block(path),
        Format::TextCell => read_textcell(path),
        Format::Csv => {
            // CSV: infer shape from the file.
            let text = fs::read_to_string(path)?;
            let rows: Vec<Vec<f64>> = text
                .lines()
                .filter(|l| !l.trim().is_empty())
                .map(|l| l.split(',').map(|v| v.trim().parse().unwrap_or(0.0)).collect())
                .collect();
            let r = rows.len();
            let c = rows.first().map_or(0, |x| x.len());
            Ok(DenseMatrix::from_vec(r, c, rows.into_iter().flatten().collect()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> String {
        let d = std::env::temp_dir().join(format!("sysds_io_{}", std::process::id()));
        fs::create_dir_all(&d).unwrap();
        d.to_string_lossy().to_string()
    }

    #[test]
    fn binary_block_roundtrip() {
        let dir = tmpdir();
        let path = format!("{dir}/bb_roundtrip");
        let m = DenseMatrix::rand(257, 129, -5.0, 5.0, 0.8, 42);
        write_binary_block(&path, &m, 100).unwrap();
        let back = read_binary_block(&path).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn binary_block_vector() {
        let dir = tmpdir();
        let path = format!("{dir}/bb_vec");
        let m = DenseMatrix::rand(1000, 1, 0.0, 1.0, 1.0, 7);
        write_binary_block(&path, &m, 128).unwrap();
        assert_eq!(read_binary_block(&path).unwrap(), m);
    }

    #[test]
    fn textcell_roundtrip() {
        let dir = tmpdir();
        let path = format!("{dir}/tc_roundtrip");
        let m = DenseMatrix::rand(31, 17, -1.0, 1.0, 0.3, 9);
        write_textcell(&path, &m).unwrap();
        let back = read_textcell(&path).unwrap();
        assert!(m.max_abs_diff(&back) < 1e-12);
    }

    #[test]
    fn mtd_roundtrip() {
        let dir = tmpdir();
        let path = format!("{dir}/meta");
        let mc = MatrixCharacteristics::new(12345, 678, 1000, 999);
        write_mtd(&path, &mc, Format::BinaryBlock).unwrap();
        let (back, fmt) = read_mtd(&path).unwrap();
        assert_eq!(back, mc);
        assert_eq!(fmt, Format::BinaryBlock);
    }

    #[test]
    fn read_matrix_dispatches_on_format() {
        let dir = tmpdir();
        let path = format!("{dir}/dispatch");
        let m = DenseMatrix::rand(10, 10, 0.0, 1.0, 1.0, 1);
        write_textcell(&path, &m).unwrap();
        let back = read_matrix(&path).unwrap();
        assert!(m.max_abs_diff(&back) < 1e-12);
    }
}
