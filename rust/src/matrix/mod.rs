//! Matrix substrate: metadata (`MatrixCharacteristics`), in-memory
//! dense/sparse representations, native operations, and serialized formats
//! (binary-block, textcell) with local-disk IO standing in for HDFS.
//!
//! Size estimation here implements the paper's `M̂(X)` (in-memory size) and
//! `M̂'(X)` (serialized size) functions (§3.1), which feed both the
//! optimizer's memory estimates (§2) and the cost model's IO times (§3.3).

pub mod dense;
pub mod io;
pub mod ops;
pub mod sparse;

pub use dense::DenseMatrix;
pub use sparse::CsrMatrix;

/// Serialized matrix format on (simulated) HDFS or local scratch space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Format {
    /// SystemML's blocked binary format (dense or sparse blocks).
    BinaryBlock,
    /// One `row col value` triple per line.
    TextCell,
    /// Comma-separated dense rows.
    Csv,
}

impl Format {
    pub fn name(&self) -> &'static str {
        match self {
            Format::BinaryBlock => "binaryblock",
            Format::TextCell => "textcell",
            Format::Csv => "csv",
        }
    }

    pub fn parse(s: &str) -> Option<Format> {
        match s {
            "binaryblock" | "binary" => Some(Format::BinaryBlock),
            "textcell" | "text" => Some(Format::TextCell),
            "csv" => Some(Format::Csv),
            _ => None,
        }
    }
}

/// Size metadata of a matrix: dimensions, blocking, and number of
/// non-zeros. Unknown values are encoded as `-1` (exactly as SystemML's
/// EXPLAIN prints them, e.g. `[1e3,1,-1,-1,-1]`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MatrixCharacteristics {
    pub rows: i64,
    pub cols: i64,
    pub brows: i64,
    pub bcols: i64,
    pub nnz: i64,
}

impl MatrixCharacteristics {
    pub fn new(rows: i64, cols: i64, blocksize: i64, nnz: i64) -> Self {
        MatrixCharacteristics { rows, cols, brows: blocksize, bcols: blocksize, nnz }
    }

    /// Fully-known dense matrix.
    pub fn dense(rows: i64, cols: i64, blocksize: i64) -> Self {
        Self::new(rows, cols, blocksize, rows.saturating_mul(cols))
    }

    /// Completely unknown characteristics.
    pub fn unknown() -> Self {
        MatrixCharacteristics { rows: -1, cols: -1, brows: -1, bcols: -1, nnz: -1 }
    }

    /// Scalar pseudo-characteristics (SystemML prints `[0,0,-1,-1,-1]`).
    pub fn scalar() -> Self {
        MatrixCharacteristics { rows: 0, cols: 0, brows: -1, bcols: -1, nnz: -1 }
    }

    pub fn dims_known(&self) -> bool {
        self.rows >= 0 && self.cols >= 0
    }

    pub fn nnz_known(&self) -> bool {
        self.nnz >= 0
    }

    pub fn is_scalar(&self) -> bool {
        self.rows == 0 && self.cols == 0
    }

    /// Number of cells, or `None` if dimensions are unknown.
    pub fn cells(&self) -> Option<f64> {
        if self.dims_known() {
            Some(self.rows as f64 * self.cols as f64)
        } else {
            None
        }
    }

    /// Sparsity `s = nnz/(m*n)` (§3.1); falls back to 1.0 (dense) when nnz
    /// is unknown, the conservative choice the compiler makes.
    pub fn sparsity(&self) -> f64 {
        match (self.cells(), self.nnz_known()) {
            (Some(c), true) if c > 0.0 => (self.nnz as f64 / c).min(1.0),
            _ => 1.0,
        }
    }

    /// Would this matrix be stored sparse in memory? (MatrixBlock rule:
    /// sparsity below threshold and more than one column.)
    pub fn sparse_in_memory(&self, sparse_threshold: f64) -> bool {
        self.dims_known() && self.cols > 1 && self.sparsity() < sparse_threshold
    }

    /// Estimated in-memory size `M̂(X)` in bytes (§3.1). Dense: 8 B/cell
    /// plus array overhead; sparse CSR: 12 B/nnz + 4 B/row. Unknown
    /// dimensions yield `f64::INFINITY`, which forces conservative
    /// (robust, MR) plans exactly like SystemML's fallback (§3.5).
    pub fn mem_estimate(&self, sparse_threshold: f64) -> f64 {
        let Some(cells) = self.cells() else { return f64::INFINITY };
        if self.is_scalar() {
            return 64.0;
        }
        if self.sparse_in_memory(sparse_threshold) {
            let nnz = self.nnz as f64;
            nnz * 12.0 + self.rows as f64 * 4.0 + 64.0
        } else {
            cells * 8.0 + 64.0
        }
    }

    /// Estimated serialized size `M̂'(X)` in bytes for a given format.
    pub fn serialized_size(&self, format: Format) -> f64 {
        let Some(cells) = self.cells() else { return f64::INFINITY };
        if self.is_scalar() {
            return 16.0;
        }
        let nnz = if self.nnz_known() { self.nnz as f64 } else { cells };
        match format {
            // Binary block: dense blocks 8 B/cell; sparse blocks ~12 B/nnz.
            // Block headers are negligible at 1000x1000 blocks.
            Format::BinaryBlock => {
                if self.sparsity() < 0.4 {
                    nnz * 12.0
                } else {
                    cells * 8.0
                }
            }
            // Textcell: ~ "row col value\n" — about 25 bytes per nnz.
            Format::TextCell => nnz * 25.0,
            // CSV: ~13 bytes per cell (dense writing).
            Format::Csv => cells * 13.0,
        }
    }

    /// Number of row blocks.
    pub fn row_blocks(&self) -> i64 {
        if self.rows < 0 || self.brows <= 0 {
            -1
        } else {
            (self.rows + self.brows - 1) / self.brows
        }
    }

    /// Number of column blocks.
    pub fn col_blocks(&self) -> i64 {
        if self.cols < 0 || self.bcols <= 0 {
            -1
        } else {
            (self.cols + self.bcols - 1) / self.bcols
        }
    }

    /// EXPLAIN rendering, e.g. `[1e4,1e3,1e3,1e3,1e7]`.
    pub fn explain(&self) -> String {
        use crate::util::fmt::fmt_dim;
        format!(
            "[{},{},{},{},{}]",
            fmt_dim(self.rows),
            fmt_dim(self.cols),
            fmt_dim(self.brows),
            fmt_dim(self.bcols),
            fmt_dim(self.nnz)
        )
    }
}

/// In-memory matrix value: dense or CSR sparse.
#[derive(Clone, Debug)]
pub enum MatrixData {
    Dense(DenseMatrix),
    Sparse(CsrMatrix),
}

impl MatrixData {
    pub fn rows(&self) -> usize {
        match self {
            MatrixData::Dense(d) => d.rows,
            MatrixData::Sparse(s) => s.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            MatrixData::Dense(d) => d.cols,
            MatrixData::Sparse(s) => s.cols,
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            MatrixData::Dense(d) => d.nnz(),
            MatrixData::Sparse(s) => s.nnz(),
        }
    }

    pub fn get(&self, r: usize, c: usize) -> f64 {
        match self {
            MatrixData::Dense(d) => d.get(r, c),
            MatrixData::Sparse(s) => s.get(r, c),
        }
    }

    /// Convert to dense (copies if sparse).
    pub fn to_dense(&self) -> DenseMatrix {
        match self {
            MatrixData::Dense(d) => d.clone(),
            MatrixData::Sparse(s) => s.to_dense(),
        }
    }

    /// Actual in-memory footprint in bytes.
    pub fn mem_size(&self) -> f64 {
        match self {
            MatrixData::Dense(d) => (d.values.len() * 8) as f64 + 64.0,
            MatrixData::Sparse(s) => {
                (s.values.len() * 12 + s.row_ptr.len() * 8) as f64 + 64.0
            }
        }
    }

    /// Characteristics of this concrete matrix at a given block size.
    pub fn characteristics(&self, blocksize: i64) -> MatrixCharacteristics {
        MatrixCharacteristics::new(
            self.rows() as i64,
            self.cols() as i64,
            blocksize,
            self.nnz() as i64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xs_scenario_sizes_match_paper() {
        // Table 1 / Figure 1: X: 1e4 x 1e3 dense = 76MB in-memory estimate,
        // 80 MB (8e7 B) serialized.
        let x = MatrixCharacteristics::dense(10_000, 1_000, 1000);
        assert_eq!(x.serialized_size(Format::BinaryBlock), 8.0e7);
        let mb = x.mem_estimate(0.4) / (1024.0 * 1024.0);
        assert_eq!(mb.round() as i64, 76); // EXPLAIN prints 76MB
        assert_eq!(x.explain(), "[1e4,1e3,1e3,1e3,1e7]");
    }

    #[test]
    fn xl_scenario_input_sizes_match_table1() {
        // Table 1: XL1 800 GB, XL2/XL3 1.6 TB, XL4 3.2 TB (decimal units).
        let xl1 = MatrixCharacteristics::dense(100_000_000, 1_000, 1000);
        assert_eq!(xl1.serialized_size(Format::BinaryBlock), 8.0e11); // 800 GB
        let xl4 = MatrixCharacteristics::dense(200_000_000, 2_000, 1000);
        assert_eq!(xl4.serialized_size(Format::BinaryBlock), 3.2e12); // 3.2 TB
    }

    #[test]
    fn sparsity_and_sparse_memory() {
        let mut mc = MatrixCharacteristics::dense(1000, 1000, 1000);
        mc.nnz = 10_000; // s = 0.01
        assert!((mc.sparsity() - 0.01).abs() < 1e-12);
        assert!(mc.sparse_in_memory(0.4));
        // Sparse estimate much smaller than dense.
        assert!(mc.mem_estimate(0.4) < 1000.0 * 1000.0 * 8.0);
    }

    #[test]
    fn vectors_never_sparse_in_memory() {
        let mut mc = MatrixCharacteristics::dense(1000, 1, 1000);
        mc.nnz = 10;
        assert!(!mc.sparse_in_memory(0.4));
    }

    #[test]
    fn unknown_dims_are_infinite_memory() {
        let mc = MatrixCharacteristics::unknown();
        assert!(mc.mem_estimate(0.4).is_infinite());
        assert_eq!(mc.explain(), "[-1,-1,-1,-1,-1]");
    }

    #[test]
    fn scalar_characteristics() {
        let mc = MatrixCharacteristics::scalar();
        assert!(mc.is_scalar());
        assert_eq!(mc.explain(), "[0,0,-1,-1,-1]");
        assert!(mc.mem_estimate(0.4) < 1024.0);
    }

    #[test]
    fn block_counts() {
        let mc = MatrixCharacteristics::dense(10_000, 1_500, 1000);
        assert_eq!(mc.row_blocks(), 10);
        assert_eq!(mc.col_blocks(), 2);
    }
}
