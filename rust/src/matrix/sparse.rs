//! CSR sparse matrix.

use super::dense::DenseMatrix;

/// Compressed sparse row matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<usize>,
    pub values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from (row, col, value) triples; duplicates are summed, zeros
    /// (including values that cancel to zero) are dropped.
    pub fn from_triples(rows: usize, cols: usize, mut triples: Vec<(usize, usize, f64)>) -> Self {
        triples.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut row_ptr = vec![0usize; rows + 1];
        let mut col_idx = Vec::with_capacity(triples.len());
        let mut values: Vec<f64> = Vec::with_capacity(triples.len());
        let mut i = 0;
        while i < triples.len() {
            let (r, c, mut v) = triples[i];
            assert!(r < rows && c < cols, "triple out of bounds");
            i += 1;
            while i < triples.len() && triples[i].0 == r && triples[i].1 == c {
                v += triples[i].2;
                i += 1;
            }
            if v != 0.0 {
                col_idx.push(c);
                values.push(v);
                row_ptr[r + 1] = col_idx.len();
            }
        }
        // Make row_ptr monotone (rows with no entries).
        for i in 1..=rows {
            if row_ptr[i] < row_ptr[i - 1] {
                row_ptr[i] = row_ptr[i - 1];
            }
        }
        CsrMatrix { rows, cols, row_ptr, col_idx, values }
    }

    /// Convert a dense matrix to CSR.
    pub fn from_dense(d: &DenseMatrix) -> Self {
        let mut row_ptr = vec![0usize; d.rows + 1];
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        for r in 0..d.rows {
            for c in 0..d.cols {
                let v = d.get(r, c);
                if v != 0.0 {
                    col_idx.push(c);
                    values.push(v);
                }
            }
            row_ptr[r + 1] = col_idx.len();
        }
        CsrMatrix { rows: d.rows, cols: d.cols, row_ptr, col_idx, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn get(&self, r: usize, c: usize) -> f64 {
        let (s, e) = (self.row_ptr[r], self.row_ptr[r + 1]);
        match self.col_idx[s..e].binary_search(&c) {
            Ok(i) => self.values[s + i],
            Err(_) => 0.0,
        }
    }

    pub fn to_dense(&self) -> DenseMatrix {
        let mut d = DenseMatrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for i in self.row_ptr[r]..self.row_ptr[r + 1] {
                d.set(r, self.col_idx[i], self.values[i]);
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_dense_csr_dense() {
        let d = DenseMatrix::rand(20, 30, -1.0, 1.0, 0.2, 5);
        let s = CsrMatrix::from_dense(&d);
        assert_eq!(s.nnz(), d.nnz());
        assert_eq!(s.to_dense(), d);
    }

    #[test]
    fn from_triples_sorted_access() {
        let s = CsrMatrix::from_triples(3, 3, vec![(2, 1, 5.0), (0, 0, 1.0), (0, 2, 2.0)]);
        assert_eq!(s.get(0, 0), 1.0);
        assert_eq!(s.get(0, 2), 2.0);
        assert_eq!(s.get(2, 1), 5.0);
        assert_eq!(s.get(1, 1), 0.0);
        assert_eq!(s.nnz(), 3);
    }

    #[test]
    fn zero_values_dropped() {
        let s = CsrMatrix::from_triples(2, 2, vec![(0, 0, 0.0), (1, 1, 3.0)]);
        assert_eq!(s.nnz(), 1);
    }

    #[test]
    fn empty_rows_monotone_ptr() {
        let s = CsrMatrix::from_triples(5, 5, vec![(4, 4, 1.0)]);
        assert!(s.row_ptr.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(s.get(4, 4), 1.0);
    }
}
