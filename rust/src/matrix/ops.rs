//! Native matrix operations.
//!
//! These are the reference implementations used by the CP runtime and the
//! MR simulator whenever no AOT-compiled PJRT kernel matches the shape
//! (the kernel registry in [`crate::runtime`] handles the hot shapes).
//! The matmul family is cache-blocked and multi-threaded via
//! `std::thread::scope` — profiled in `benches/cp_ops.rs`.

use super::dense::DenseMatrix;

/// Cache block edge for the blocked matmul inner kernels.
const BLK: usize = 64;

/// Transpose.
pub fn transpose(a: &DenseMatrix) -> DenseMatrix {
    let mut out = DenseMatrix::zeros(a.cols, a.rows);
    // Blocked transpose for cache friendliness.
    for rb in (0..a.rows).step_by(BLK) {
        for cb in (0..a.cols).step_by(BLK) {
            for r in rb..(rb + BLK).min(a.rows) {
                for c in cb..(cb + BLK).min(a.cols) {
                    out.values[c * a.rows + r] = a.values[r * a.cols + c];
                }
            }
        }
    }
    out
}

/// General matrix multiply C = A * B (single-threaded, cache-blocked ikj).
pub fn matmult_st(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    assert_eq!(a.cols, b.rows, "matmult shape mismatch");
    let mut c = DenseMatrix::zeros(a.rows, b.cols);
    matmult_into(a, b, &mut c.values, 0, a.rows);
    c
}

/// Multi-threaded matrix multiply, splitting rows of A across `threads`.
pub fn matmult(a: &DenseMatrix, b: &DenseMatrix, threads: usize) -> DenseMatrix {
    assert_eq!(a.cols, b.rows, "matmult shape mismatch");
    let mut c = DenseMatrix::zeros(a.rows, b.cols);
    let t = threads.clamp(1, a.rows.max(1));
    if t == 1 || a.rows * b.cols < 64 * 64 {
        matmult_into(a, b, &mut c.values, 0, a.rows);
        return c;
    }
    let chunk_rows = (a.rows + t - 1) / t;
    let n = b.cols;
    let chunks: Vec<(usize, &mut [f64])> = c
        .values
        .chunks_mut(chunk_rows * n)
        .enumerate()
        .map(|(i, ch)| (i * chunk_rows, ch))
        .collect();
    std::thread::scope(|s| {
        for (row0, ch) in chunks {
            s.spawn(move || {
                let rows = ch.len() / n;
                matmult_into(a, b, ch, row0, rows);
            });
        }
    });
    c
}

/// Inner kernel: compute `rows` rows of A*B starting at `row0` into `out`
/// (row-major, `rows * b.cols` long).
fn matmult_into(a: &DenseMatrix, b: &DenseMatrix, out: &mut [f64], row0: usize, rows: usize) {
    let n = b.cols;
    let k = a.cols;
    for kb in (0..k).step_by(BLK) {
        let kend = (kb + BLK).min(k);
        for i in 0..rows {
            let arow = a.row(row0 + i);
            let crow = &mut out[i * n..(i + 1) * n];
            // 4-way k-unroll: one C-row pass per four B rows.
            let mut kk = kb;
            while kk + 4 <= kend {
                let (a0, a1, a2, a3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
                if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                    let b0 = b.row(kk);
                    let b1 = b.row(kk + 1);
                    let b2 = b.row(kk + 2);
                    let b3 = b.row(kk + 3);
                    for j in 0..n {
                        crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                }
                kk += 4;
            }
            while kk < kend {
                let av = arow[kk];
                if av != 0.0 {
                    let brow = b.row(kk);
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
                kk += 1;
            }
        }
    }
}

/// Transpose-self matrix multiply: `t(X) %*% X` exploiting result symmetry
/// (the paper's `tsmm` physical operator, Eq. 2: only half the computation).
pub fn tsmm_left(x: &DenseMatrix, threads: usize) -> DenseMatrix {
    let n = x.cols;
    let mut c = DenseMatrix::zeros(n, n);
    let t = threads.clamp(1, n.max(1));
    // Parallelise over output column panels; each thread computes the upper
    // triangle entries of its panel; mirror at the end.
    let panel = (n + t - 1) / t;
    let panels: Vec<(usize, &mut [f64])> = c
        .values
        .chunks_mut(panel * n)
        .enumerate()
        .map(|(i, ch)| (i * panel, ch))
        .collect();
    std::thread::scope(|s| {
        for (i0, ch) in panels {
            s.spawn(move || {
                let rows = ch.len() / n;
                // 4-row register blocking: one pass over each C row per 4
                // input rows quarters the C-row load/store traffic.
                let mut r = 0;
                while r + 4 <= x.rows {
                    let (x0, x1, x2, x3) =
                        (x.row(r), x.row(r + 1), x.row(r + 2), x.row(r + 3));
                    for i in 0..rows {
                        let (v0, v1, v2, v3) =
                            (x0[i0 + i], x1[i0 + i], x2[i0 + i], x3[i0 + i]);
                        if v0 == 0.0 && v1 == 0.0 && v2 == 0.0 && v3 == 0.0 {
                            continue;
                        }
                        let crow = &mut ch[i * n..(i + 1) * n];
                        // only j >= i0+i (upper triangle)
                        for j in (i0 + i)..n {
                            crow[j] += v0 * x0[j] + v1 * x1[j] + v2 * x2[j] + v3 * x3[j];
                        }
                    }
                    r += 4;
                }
                while r < x.rows {
                    let xr = x.row(r);
                    for i in 0..rows {
                        let v = xr[i0 + i];
                        if v == 0.0 {
                            continue;
                        }
                        let crow = &mut ch[i * n..(i + 1) * n];
                        for j in (i0 + i)..n {
                            crow[j] += v * xr[j];
                        }
                    }
                    r += 1;
                }
            });
        }
    });
    // Mirror upper to lower triangle.
    for i in 0..n {
        for j in (i + 1)..n {
            c.values[j * n + i] = c.values[i * n + j];
        }
    }
    c
}

/// Elementwise binary operation.
pub fn ewise(a: &DenseMatrix, b: &DenseMatrix, f: impl Fn(f64, f64) -> f64) -> DenseMatrix {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "ewise shape mismatch");
    let values = a.values.iter().zip(&b.values).map(|(x, y)| f(*x, *y)).collect();
    DenseMatrix { rows: a.rows, cols: a.cols, values }
}

/// Elementwise op with a scalar.
pub fn ewise_scalar(a: &DenseMatrix, s: f64, f: impl Fn(f64, f64) -> f64) -> DenseMatrix {
    let values = a.values.iter().map(|x| f(*x, s)).collect();
    DenseMatrix { rows: a.rows, cols: a.cols, values }
}

/// Elementwise unary op.
pub fn unary(a: &DenseMatrix, f: impl Fn(f64) -> f64) -> DenseMatrix {
    DenseMatrix { rows: a.rows, cols: a.cols, values: a.values.iter().map(|x| f(*x)).collect() }
}

/// Column vector -> diagonal matrix, or square matrix -> diagonal column
/// vector (DML `diag`, SystemML `r(diag)`).
pub fn diag(a: &DenseMatrix) -> DenseMatrix {
    if a.cols == 1 {
        let n = a.rows;
        let mut out = DenseMatrix::zeros(n, n);
        for i in 0..n {
            out.values[i * n + i] = a.values[i];
        }
        out
    } else {
        assert_eq!(a.rows, a.cols, "diag needs vector or square matrix");
        let n = a.rows;
        let mut out = DenseMatrix::zeros(n, 1);
        for i in 0..n {
            out.values[i] = a.values[i * n + i];
        }
        out
    }
}

/// Horizontal concatenation (DML `append`/`cbind`).
pub fn cbind(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    assert_eq!(a.rows, b.rows, "cbind row mismatch");
    let cols = a.cols + b.cols;
    let mut out = DenseMatrix::zeros(a.rows, cols);
    for r in 0..a.rows {
        out.values[r * cols..r * cols + a.cols].copy_from_slice(a.row(r));
        out.values[r * cols + a.cols..(r + 1) * cols].copy_from_slice(b.row(r));
    }
    out
}

/// Vertical concatenation (DML `rbind`).
pub fn rbind(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    assert_eq!(a.cols, b.cols, "rbind col mismatch");
    let mut values = a.values.clone();
    values.extend_from_slice(&b.values);
    DenseMatrix { rows: a.rows + b.rows, cols: a.cols, values }
}

/// Full aggregate sum.
pub fn sum(a: &DenseMatrix) -> f64 {
    // Kahan-compensated like SystemML's ak+ [4].
    let mut s = 0.0;
    let mut c = 0.0;
    for v in &a.values {
        let y = v - c;
        let t = s + y;
        c = (t - s) - y;
        s = t;
    }
    s
}

/// Row sums (m x 1).
pub fn row_sums(a: &DenseMatrix) -> DenseMatrix {
    let mut out = DenseMatrix::zeros(a.rows, 1);
    for r in 0..a.rows {
        out.values[r] = a.row(r).iter().sum();
    }
    out
}

/// Column sums (1 x n).
pub fn col_sums(a: &DenseMatrix) -> DenseMatrix {
    let mut out = DenseMatrix::zeros(1, a.cols);
    for r in 0..a.rows {
        for c in 0..a.cols {
            out.values[c] += a.get(r, c);
        }
    }
    out
}

/// Solve the linear system `A x = b` via LU decomposition with partial
/// pivoting (DML `solve`, SystemML `b(solve)`).
pub fn solve(a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix, String> {
    if a.rows != a.cols {
        return Err("solve: A must be square".into());
    }
    if b.rows != a.rows {
        return Err("solve: dimension mismatch".into());
    }
    let n = a.rows;
    let m = b.cols;
    let mut lu = a.values.clone();
    let mut x = b.values.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    for k in 0..n {
        // partial pivot
        let mut p = k;
        let mut maxv = lu[perm[k] * n + k].abs();
        for i in (k + 1)..n {
            let v = lu[perm[i] * n + k].abs();
            if v > maxv {
                maxv = v;
                p = i;
            }
        }
        if maxv < 1e-300 {
            return Err("solve: singular matrix".into());
        }
        perm.swap(k, p);
        let pk = perm[k];
        let pivot = lu[pk * n + k];
        for i in (k + 1)..n {
            let pi = perm[i];
            let f = lu[pi * n + k] / pivot;
            lu[pi * n + k] = f;
            for j in (k + 1)..n {
                lu[pi * n + j] -= f * lu[pk * n + j];
            }
            for j in 0..m {
                x[pi * m + j] -= f * x[pk * m + j];
            }
        }
    }
    // Back substitution.
    let mut out = vec![0.0; n * m];
    for j in 0..m {
        for i in (0..n).rev() {
            let pi = perm[i];
            let mut s = x[pi * m + j];
            for k2 in (i + 1)..n {
                s -= lu[pi * n + k2] * out[k2 * m + j];
            }
            out[i * m + j] = s / lu[pi * n + i];
        }
    }
    Ok(DenseMatrix { rows: n, cols: m, values: out })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn randm(r: usize, c: usize, seed: u64) -> DenseMatrix {
        DenseMatrix::rand(r, c, -1.0, 1.0, 1.0, seed)
    }

    #[test]
    fn transpose_involution() {
        let a = randm(17, 29, 1);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn matmult_matches_naive() {
        let a = randm(13, 7, 2);
        let b = randm(7, 11, 3);
        let c = matmult(&a, &b, 4);
        for i in 0..13 {
            for j in 0..11 {
                let expect: f64 = (0..7).map(|k| a.get(i, k) * b.get(k, j)).sum();
                assert!((c.get(i, j) - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn matmult_threaded_equals_single() {
        let a = randm(130, 40, 4);
        let b = randm(40, 70, 5);
        assert!(matmult(&a, &b, 8).max_abs_diff(&matmult_st(&a, &b)) < 1e-12);
    }

    #[test]
    fn tsmm_matches_explicit_product() {
        let x = randm(50, 20, 6);
        let explicit = matmult_st(&transpose(&x), &x);
        let fast = tsmm_left(&x, 4);
        assert!(fast.max_abs_diff(&explicit) < 1e-10);
    }

    #[test]
    fn tsmm_result_symmetric_property() {
        prop::forall(
            25,
            77,
            |r| {
                let m = r.range_i64(1, 40) as usize;
                let n = r.range_i64(1, 30) as usize;
                DenseMatrix::rand(m, n, -2.0, 2.0, 0.7, r.next_u64())
            },
            |x| {
                let c = tsmm_left(x, 3);
                for i in 0..c.rows {
                    for j in 0..c.cols {
                        if (c.get(i, j) - c.get(j, i)).abs() > 1e-10 {
                            return Err(format!("asymmetric at ({i},{j})"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn ytx_transpose_rewrite_property() {
        // (t(X) %*% y) == t(t(y) %*% X) — the HOP-LOP rewrite of Figure 2.
        prop::forall(
            25,
            88,
            |r| {
                let m = r.range_i64(1, 30) as usize;
                let n = r.range_i64(1, 20) as usize;
                let seed = r.next_u64();
                (DenseMatrix::rand(m, n, -1.0, 1.0, 1.0, seed),
                 DenseMatrix::rand(m, 1, -1.0, 1.0, 1.0, seed ^ 1))
            },
            |(x, y)| {
                let a = matmult_st(&transpose(x), y);
                let b = transpose(&matmult_st(&transpose(y), x));
                if a.max_abs_diff(&b) < 1e-10 { Ok(()) } else { Err("rewrite mismatch".into()) }
            },
        );
    }

    #[test]
    fn diag_vector_roundtrip() {
        let v = randm(9, 1, 7);
        let d = diag(&v);
        assert_eq!(d.rows, 9);
        assert_eq!(diag(&d), v);
    }

    #[test]
    fn cbind_rbind_shapes() {
        let a = randm(4, 3, 8);
        let b = randm(4, 2, 9);
        let c = cbind(&a, &b);
        assert_eq!((c.rows, c.cols), (4, 5));
        assert_eq!(c.get(2, 3), b.get(2, 0));
        let d = rbind(&a, &randm(2, 3, 10));
        assert_eq!((d.rows, d.cols), (6, 3));
    }

    #[test]
    fn solve_recovers_known_solution() {
        // Build a well-conditioned SPD system A = X'X + I, known beta.
        let x = randm(40, 10, 11);
        let mut a = tsmm_left(&x, 2);
        for i in 0..10 {
            a.values[i * 10 + i] += 1.0;
        }
        let beta = randm(10, 1, 12);
        let b = matmult_st(&a, &beta);
        let sol = solve(&a, &b).unwrap();
        assert!(sol.max_abs_diff(&beta) < 1e-8);
    }

    #[test]
    fn solve_rejects_singular() {
        let a = DenseMatrix::zeros(3, 3);
        let b = DenseMatrix::zeros(3, 1);
        assert!(solve(&a, &b).is_err());
    }

    #[test]
    fn sums_and_aggregates() {
        let a = DenseMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(sum(&a), 21.0);
        assert_eq!(row_sums(&a).values, vec![6.0, 15.0]);
        assert_eq!(col_sums(&a).values, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn ewise_ops() {
        let a = DenseMatrix::filled(2, 2, 3.0);
        let b = DenseMatrix::filled(2, 2, 4.0);
        assert_eq!(ewise(&a, &b, |x, y| x + y).values, vec![7.0; 4]);
        assert_eq!(ewise_scalar(&a, 2.0, |x, y| x * y).values, vec![6.0; 4]);
        assert_eq!(unary(&a, |x| -x).values, vec![-3.0; 4]);
    }
}
