//! Formatting helpers shared by EXPLAIN output and reports.

/// Format a byte count the way SystemML's EXPLAIN does (whole MB).
pub fn fmt_mb(bytes: f64) -> String {
    format!("{}MB", (bytes / (1024.0 * 1024.0)).round() as i64)
}

/// Human-readable byte count with autoscaled units.
pub fn fmt_bytes(bytes: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = bytes;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", v as i64, UNITS[u])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

/// Format seconds the way the paper's cost-annotated plans do: scientific
/// notation for tiny values, fixed-point otherwise (e.g. `4.7E-9s`, `3.31s`).
pub fn fmt_secs(s: f64) -> String {
    if s == 0.0 {
        "0s".to_string()
    } else if s < 1e-3 {
        let exp = s.log10().floor() as i32;
        let mant = s / 10f64.powi(exp);
        format!("{mant:.1}E{exp}s")
    } else if s < 10.0 {
        format!("{s:.3}s")
    } else {
        format!("{s:.1}s")
    }
}

/// Normalise the process-id scratch path that runtime-plan generation
/// embeds (`scratch_space//_p1234//` → `scratch_space//_pPID//`) so
/// EXPLAIN output is stable across processes. Single source of truth for
/// the golden-snapshot tests and the GDF plan diff.
pub fn normalize_scratch_pid(text: &str) -> String {
    let mut out = String::with_capacity(text.len());
    let mut rest = text;
    while let Some(pos) = rest.find("//_p") {
        let (head, tail) = rest.split_at(pos + 4);
        out.push_str(head);
        out.push_str("PID");
        rest = tail.trim_start_matches(|c: char| c.is_ascii_digit());
    }
    out.push_str(rest);
    out
}

/// Format a dimension that may be unknown (-1), SystemML-style (`1e4` or `-1`).
pub fn fmt_dim(d: i64) -> String {
    if d < 0 {
        return "-1".to_string();
    }
    // Use short scientific form for powers of ten like the paper's Figure 1.
    let f = d as f64;
    let exp = f.log10();
    if d > 0 && exp.fract() == 0.0 && d >= 1000 {
        format!("1e{}", exp as i64)
    } else if d >= 1000 && (f / 10f64.powf(exp.floor())).fract() == 0.0 {
        format!("{}e{}", (f / 10f64.powf(exp.floor())) as i64, exp.floor() as i64)
    } else {
        d.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mb_rounding() {
        assert_eq!(fmt_mb(80.0 * 1024.0 * 1024.0), "80MB");
        assert_eq!(fmt_mb(0.0), "0MB");
    }

    #[test]
    fn bytes_scaling() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert!(fmt_bytes(80e6).contains("MB"));
        assert!(fmt_bytes(1.6e12).contains("TB"));
    }

    #[test]
    fn secs_matches_paper_style() {
        assert_eq!(fmt_secs(0.0), "0s");
        assert!(fmt_secs(4.7e-9).starts_with("4.7E-9"));
        assert_eq!(fmt_secs(3.31), "3.310s");
        assert_eq!(fmt_secs(606.9), "606.9s");
    }

    #[test]
    fn scratch_pid_normalised() {
        let text = "CP createvar _mVar2 scratch_space//_p4242//_t0/temp2 true";
        let n = normalize_scratch_pid(text);
        assert_eq!(n, "CP createvar _mVar2 scratch_space//_pPID//_t0/temp2 true");
        assert_eq!(normalize_scratch_pid("no pid here"), "no pid here");
    }

    #[test]
    fn dims_scientific() {
        assert_eq!(fmt_dim(10_000), "1e4");
        assert_eq!(fmt_dim(1000), "1e3");
        assert_eq!(fmt_dim(200_000_000), "2e8");
        assert_eq!(fmt_dim(-1), "-1");
        assert_eq!(fmt_dim(7), "7");
    }
}
