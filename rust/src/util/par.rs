//! Minimal data-parallel map over a slice (stand-in for rayon, which is
//! unavailable in the hermetic offline build).
//!
//! [`par_map`] runs a closure over every item of a slice on a scoped
//! thread pool with an atomic work-stealing index, so unevenly-sized
//! work items (e.g. XS vs XL4 compile+cost cells in the scenario sweep)
//! balance across workers. Results are returned **in input order**, so
//! callers are deterministic regardless of scheduling: each worker's
//! bucket is ascending by construction (the atomic index only grows),
//! and the buckets are k-way merged directly into the result vector —
//! no intermediate `Vec<Option<R>>` scatter pass, no per-item unwrap.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (available parallelism).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every item of `items` using up to `threads` workers and
/// return the results in input order. `f` receives `(index, &item)`.
///
/// With `threads <= 1` (or one item) this degrades to a plain serial
/// map with no thread overhead. Panics in `f` propagate to the caller.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let t = threads.max(1).min(items.len().max(1));
    if t <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let next = AtomicUsize::new(0);
    let f = &f;
    let next = &next;
    let buckets: Vec<Vec<(usize, R)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..t)
            .map(|_| {
                s.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        out.push((i, f(i, &items[i])));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
            .collect()
    });
    merge_indexed(items.len(), buckets)
}

/// K-way merge of per-worker `(index, result)` buckets into input order.
/// Every bucket is ascending by index and the indices across buckets
/// partition `0..n`, so for each wanted position exactly one bucket
/// fronts it — results move straight into their final slot.
fn merge_indexed<R>(n: usize, buckets: Vec<Vec<(usize, R)>>) -> Vec<R> {
    let mut iters: Vec<_> = buckets.into_iter().map(|b| b.into_iter().peekable()).collect();
    let mut out = Vec::with_capacity(n);
    for want in 0..n {
        let pos = iters
            .iter_mut()
            .position(|it| matches!(it.peek(), Some(&(i, _)) if i == want))
            .expect("par_map produced every index exactly once");
        // the peeked element is `want`'s result
        out.push(iters[pos].next().expect("peeked element exists").1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(&items, 8, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (1..=50).collect();
        let serial = par_map(&items, 1, |_, &x| x * x);
        let parallel = par_map(&items, 6, |_, &x| x * x);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<i32> = vec![];
        assert!(par_map(&none, 4, |_, &x| x).is_empty());
        assert_eq!(par_map(&[7], 4, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn uneven_work_items_all_complete() {
        let items: Vec<u64> = (0..40).map(|i| (i % 7) * 100_000).collect();
        let out = par_map(&items, 4, |_, &n| (0..n).fold(0u64, |a, b| a.wrapping_add(b)));
        assert_eq!(out.len(), 40);
        // spot-check against the closed form n*(n-1)/2
        for (i, &n) in items.iter().enumerate() {
            assert_eq!(out[i], n.wrapping_mul(n.wrapping_sub(1)) / 2);
        }
    }

    #[test]
    fn merge_handles_adversarial_bucket_shapes() {
        // hand-built buckets: empty, interleaved, singleton
        let buckets: Vec<Vec<(usize, u32)>> =
            vec![vec![(1, 10), (3, 30)], vec![], vec![(0, 0), (2, 20), (4, 40)]];
        assert_eq!(merge_indexed(5, buckets), vec![0, 10, 20, 30, 40]);
    }

    /// Property (satellite): for random sizes, thread counts and
    /// work-skew patterns, `par_map` returns exactly the serial map —
    /// same values, same order — on every thread count.
    #[test]
    fn prop_deterministic_across_thread_counts() {
        forall(
            25,
            0x9A12,
            |r| {
                let len = r.below(200) as usize;
                let threads = 1 + r.below(16) as usize;
                let skew = 1 + r.below(5) as u64;
                (len, threads, skew)
            },
            |&(len, threads, skew)| {
                let items: Vec<u64> = (0..len as u64).collect();
                // unevenly-sized work: burn cycles proportional to i % skew
                let work = |i: usize, x: &u64| {
                    let spin = (i as u64 % skew) * 1_000;
                    let mut acc = *x;
                    for j in 0..spin {
                        acc = acc.wrapping_mul(6364136223846793005).wrapping_add(j);
                    }
                    (*x, acc)
                };
                let reference: Vec<(u64, u64)> =
                    items.iter().enumerate().map(|(i, x)| work(i, x)).collect();
                let parallel = par_map(&items, threads, work);
                if parallel == reference {
                    Ok(())
                } else {
                    Err(format!("len={len} threads={threads} skew={skew}: order diverged"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panics_propagate() {
        let items: Vec<u32> = (0..64).collect();
        let _ = par_map(&items, 4, |_, &x| {
            if x == 33 {
                panic!("boom");
            }
            x
        });
    }
}
