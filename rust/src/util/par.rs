//! Minimal data-parallel map over a slice (stand-in for rayon, which is
//! unavailable in the hermetic offline build).
//!
//! [`par_map`] runs a closure over every item of a slice on a scoped
//! thread pool with an atomic work-stealing index, so unevenly-sized
//! work items (e.g. XS vs XL4 compile+cost cells in the scenario sweep)
//! balance across workers. Results are returned **in input order**, so
//! callers are deterministic regardless of scheduling.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (available parallelism).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every item of `items` using up to `threads` workers and
/// return the results in input order. `f` receives `(index, &item)`.
///
/// With `threads <= 1` (or one item) this degrades to a plain serial
/// map with no thread overhead. Panics in `f` propagate to the caller.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let t = threads.max(1).min(items.len().max(1));
    if t <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let next = AtomicUsize::new(0);
    let f = &f;
    let next = &next;
    let buckets: Vec<Vec<(usize, R)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..t)
            .map(|_| {
                s.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        out.push((i, f(i, &items[i])));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
            .collect()
    });
    let mut results: Vec<Option<R>> = Vec::with_capacity(items.len());
    results.resize_with(items.len(), || None);
    for bucket in buckets {
        for (i, r) in bucket {
            results[i] = Some(r);
        }
    }
    results.into_iter().map(|r| r.expect("par_map index filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map(&items, 8, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (1..=50).collect();
        let serial = par_map(&items, 1, |_, &x| x * x);
        let parallel = par_map(&items, 6, |_, &x| x * x);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<i32> = vec![];
        assert!(par_map(&none, 4, |_, &x| x).is_empty());
        assert_eq!(par_map(&[7], 4, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn uneven_work_items_all_complete() {
        let items: Vec<u64> = (0..40).map(|i| (i % 7) * 100_000).collect();
        let out = par_map(&items, 4, |_, &n| (0..n).fold(0u64, |a, b| a.wrapping_add(b)));
        assert_eq!(out.len(), 40);
        // spot-check against the closed form n*(n-1)/2
        for (i, &n) in items.iter().enumerate() {
            assert_eq!(out[i], n.wrapping_mul(n.wrapping_sub(1)) / 2);
        }
    }
}
