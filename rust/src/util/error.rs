//! Minimal `anyhow`-compatible error handling (the real crate is
//! unavailable in the hermetic offline build, like criterion/proptest —
//! see [`crate::util`]).
//!
//! Provides the exact surface the runtime modules use: an opaque
//! [`Error`] with a context chain, a [`Result`] alias defaulting the
//! error type, the [`anyhow!`]/[`bail!`] macros, and a [`Context`]
//! extension trait for `Result` and `Option`.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error`, so conversions from concrete error types stay
//! unambiguous.

use std::fmt;

/// Opaque error: a message plus outer-to-inner context chain.
pub struct Error {
    /// Rendered message; context wraps as `"context: inner"`.
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    /// Wrap this error with an outer context message.
    pub fn context(self, c: impl fmt::Display) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(e: String) -> Self {
        Error { msg: e }
    }
}

impl From<&str> for Error {
    fn from(e: &str) -> Self {
        Error::msg(e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`), `anyhow::Context`-style.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::util::error::anyhow!($($t)*))
    };
}

pub(crate) use anyhow;
pub(crate) use bail;

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    #[test]
    fn io_error_converts_and_takes_context() {
        let e = fails_io().context("reading X").unwrap_err();
        let s = format!("{e}");
        assert!(s.contains("reading X"), "{s}");
        assert!(s.contains("gone"), "{s}");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative input {x}");
            }
            Err(anyhow!("x = {}, always fails", x))
        }
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(2).unwrap_err()), "x = 2, always fails");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e:?}"), "missing value");
    }

    #[test]
    fn with_context_chains() {
        let e = fails_io().with_context(|| format!("step {}", 3)).unwrap_err();
        assert!(format!("{e}").starts_with("step 3: "));
    }
}
