//! Tiny property-based testing helper (proptest is unavailable offline).
//!
//! `forall(cases, seed, gen, check)` runs `check` on `cases` random inputs
//! produced by `gen` from a deterministic [`Rng`]; on failure it panics with
//! the case index and seed so the exact failing input can be reproduced by
//! rerunning with `case_seed`.

use super::rng::Rng;

/// Run `check` on `cases` randomly generated inputs.
///
/// Panics with a reproducible seed on the first failing case.
pub fn forall<T: std::fmt::Debug>(
    cases: usize,
    seed: u64,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    for i in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(i as u64);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed at case {i}/{cases} (case_seed={case_seed}):\n  input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Convenience assertion for approximate float equality in properties.
pub fn close(a: f64, b: f64, rel: f64) -> Result<(), String> {
    let denom = a.abs().max(b.abs()).max(1e-12);
    if (a - b).abs() / denom <= rel {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (rel tol {rel})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(50, 1, |r| r.range_i64(0, 100), |x| {
            count += 1;
            if *x <= 100 { Ok(()) } else { Err("out of range".into()) }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(50, 2, |r| r.range_i64(0, 100), |x| {
            if *x < 0 { Ok(()) } else { Err("always fails".into()) }
        });
    }

    #[test]
    fn close_tolerates_relative_error() {
        assert!(close(100.0, 100.5, 0.01).is_ok());
        assert!(close(100.0, 120.0, 0.01).is_err());
    }
}
