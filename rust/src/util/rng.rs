//! Deterministic xoshiro256** PRNG (no external deps; reproducible across
//! runs, which the MR simulator and data generators require).

/// xoshiro256** random number generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's method without bias correction is fine for our use; use
        // simple modulo of a wide value to keep it deterministic and cheap.
        if n == 0 {
            return 0;
        }
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Bernoulli trial with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// SplitMix64 finalizer: one stateless avalanche round over a counter.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Counter-mode fault-injection hash: a uniform f64 in `[0, 1)` keyed by
/// `(seed, job, task, attempt)`. Stateless — every (key, counter) tuple
/// maps to the same value regardless of evaluation order, which is what
/// makes fault injection bitwise-stable across `--threads` settings: the
/// thread that happens to run a task cannot perturb whether it fails.
/// Built from chained SplitMix64 finalizer rounds (one per key component)
/// so adjacent counters decorrelate fully.
pub fn fault_roll(seed: u64, job: u64, task: u64, attempt: u64) -> f64 {
    let mut z = splitmix64(seed);
    z = splitmix64(z ^ job.wrapping_mul(0xA24BAED4963EE407));
    z = splitmix64(z ^ task.wrapping_mul(0x9FB21C651E98DF25));
    z = splitmix64(z ^ attempt.wrapping_mul(0xD6E8FEB86659FD93));
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(0.0, 2.0)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_i64(-2, 2);
            assert!((-2..=2).contains(&v));
            seen_lo |= v == -2;
            seen_hi |= v == 2;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn fault_roll_is_stateless_and_keyed() {
        // Same key -> same roll, bitwise, in any evaluation order.
        assert_eq!(
            fault_roll(42, 3, 17, 1).to_bits(),
            fault_roll(42, 3, 17, 1).to_bits()
        );
        // Each key component perturbs the roll.
        let base = fault_roll(42, 3, 17, 1);
        assert_ne!(base.to_bits(), fault_roll(43, 3, 17, 1).to_bits());
        assert_ne!(base.to_bits(), fault_roll(42, 4, 17, 1).to_bits());
        assert_ne!(base.to_bits(), fault_roll(42, 3, 18, 1).to_bits());
        assert_ne!(base.to_bits(), fault_roll(42, 3, 17, 2).to_bits());
    }

    #[test]
    fn fault_roll_uniform_in_unit_interval() {
        let n = 10_000;
        let mut sum = 0.0;
        for t in 0..n {
            let x = fault_roll(7, 0, t, 0);
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }
}
