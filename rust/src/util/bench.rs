//! Minimal micro-benchmark harness.
//!
//! criterion is not available in the offline build environment, so the
//! `benches/` targets (declared with `harness = false`) use this harness
//! instead: warmup, fixed-duration sampling, and median / mean / p95
//! reporting with ns..s autoscaling.

use std::time::{Duration, Instant};

/// Result statistics of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    /// One-line human-readable summary.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10}  (mean {:>10}, p95 {:>10}, n={})",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.p95),
            self.samples
        )
    }
}

/// Format a duration with autoscaled units.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Fixed-budget benchmark runner.
pub struct Bencher {
    warmup: Duration,
    measure: Duration,
    max_samples: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(1200),
            max_samples: 2000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Override the measurement budget (e.g. for slow end-to-end benches).
    pub fn with_budget(mut self, warmup: Duration, measure: Duration) -> Self {
        self.warmup = warmup;
        self.measure = measure;
        self
    }

    /// Benchmark a closure; the closure's return value is black-boxed.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure && samples.len() < self.max_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let n = samples.len().max(1);
        let total: Duration = samples.iter().sum();
        let stats = BenchStats {
            name: name.to_string(),
            samples: n,
            mean: total / n as u32,
            median: samples.get(n / 2).copied().unwrap_or_default(),
            p95: samples.get((n * 95) / 100).copied().unwrap_or_default(),
            min: samples.first().copied().unwrap_or_default(),
            max: samples.last().copied().unwrap_or_default(),
        };
        println!("{}", stats.line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All results collected so far.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let mut b = Bencher::new().with_budget(Duration::from_millis(5), Duration::from_millis(20));
        let s = b.bench("noop", || 1 + 1).clone();
        assert!(s.samples > 0);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(12)).contains(" s"));
    }
}
