//! Small self-contained utilities: a deterministic PRNG, a micro-benchmark
//! harness (stand-in for criterion, which is unavailable offline), a
//! property-testing helper (stand-in for proptest), and formatting helpers.

pub mod bench;
pub mod fmt;
pub mod prop;
pub mod rng;

pub use bench::Bencher;
pub use rng::Rng;
