//! Small self-contained utilities: a deterministic PRNG, a micro-benchmark
//! harness (stand-in for criterion, which is unavailable offline), a
//! property-testing helper (stand-in for proptest), an `anyhow`-style
//! error type (stand-in for anyhow), a data-parallel map (stand-in for
//! rayon), and formatting helpers.

pub mod bench;
pub mod error;
pub mod fmt;
pub mod par;
pub mod prop;
pub mod rng;

pub use bench::Bencher;
pub use rng::Rng;
