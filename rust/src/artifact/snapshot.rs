//! Cost-cache snapshots: export the totals-only entries of a
//! [`CostCache`] to disk and shard-merge them back on load.
//!
//! Only totals-only entries (the flat `Block { label: "", children: [] }`
//! nodes the candidate evaluator's `emit_nodes = false` path caches) are
//! exported: they are the shape every optimizer replays, and the
//! `emit_nodes` bit participates in the knob fingerprint so the two
//! costing modes can never alias. Each entry carries its full 384-bit
//! cache key (structural × state × knob fingerprints), the bitwise cost
//! total and the *outgoing* variable-state table needed to resume
//! sequential block costing after a hit — the same `CachedBlockCost`
//! payload the in-process cache stores.
//!
//! Import goes through the normal [`CostCache`] insert path, so the FIFO
//! capacity bound and shard layout are respected: loading a snapshot
//! into a smaller cache keeps the first `capacity` entries rather than
//! growing without bound.

use std::sync::Arc;

use crate::cost::cache::{CostCache, ExportedEntry};
use crate::cost::vars::{DataInfo, DataState};
use crate::matrix::{Format, MatrixCharacteristics};

use super::codec::{escape, f64_from_hex, f64_to_hex, unescape, Reader, Writer};

/// Header kind token for cache snapshots.
pub const KIND: &str = "costcache";

/// A serializable export of a [`CostCache`]'s totals-only entries.
#[derive(Clone, Debug)]
pub struct CacheSnapshot {
    capacity: usize,
    entries: Vec<ExportedEntry>,
}

impl CacheSnapshot {
    /// Snapshot every totals-only entry of `cache` (deterministic order:
    /// sorted by cache key).
    pub fn from_cache(cache: &CostCache) -> Self {
        CacheSnapshot {
            capacity: cache.stats().capacity,
            entries: cache.export_totals(),
        }
    }

    /// An empty snapshot that remembers only a capacity (used by tests
    /// and as a neutral element for merging).
    pub fn empty(capacity: usize) -> Self {
        CacheSnapshot { capacity, entries: Vec::new() }
    }

    /// Number of exported entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the snapshot holds no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The capacity of the cache this snapshot was taken from.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Merge the snapshot into an existing cache through the normal
    /// insert path (shard routing and FIFO capacity bounds apply).
    /// Returns the number of entries offered.
    pub fn apply(&self, cache: &CostCache) -> usize {
        cache.import_totals(&self.entries)
    }

    /// Build a fresh cache sized like the source cache (but never
    /// smaller than the snapshot itself) and load every entry into it.
    pub fn into_cache(&self) -> Arc<CostCache> {
        let cache = Arc::new(CostCache::new(self.capacity.max(self.entries.len())));
        self.apply(&cache);
        cache
    }

    /// Serialize to the artifact text form.
    pub fn encode(&self) -> String {
        let mut w = Writer::new(KIND);
        w.section("meta");
        w.put_usize("capacity", self.capacity);
        w.put_usize("entries", self.entries.len());
        w.section("entries");
        for e in &self.entries {
            w.put_raw("e", &encode_entry(e));
        }
        w.finish()
    }

    /// Parse from the artifact text form.
    pub fn decode(text: &str) -> Result<Self, String> {
        let reader = Reader::parse(text)?;
        if reader.kind() != KIND {
            return Err(format!("artifact: expected a '{KIND}' artifact, got '{}'", reader.kind()));
        }
        Self::decode_from(&reader)
    }

    pub(crate) fn decode_from(reader: &Reader) -> Result<Self, String> {
        let meta = reader.section("meta")?;
        let capacity = meta.usize("capacity")?;
        let declared = meta.usize("entries")?;
        let section = reader.section("entries")?;
        let rows = section.get_all("e");
        if rows.len() != declared {
            return Err(format!(
                "artifact: snapshot declares {declared} entries but carries {} — truncated?",
                rows.len()
            ));
        }
        let mut entries = Vec::with_capacity(rows.len());
        for (i, row) in rows.iter().enumerate() {
            entries.push(
                decode_entry(row).map_err(|e| format!("artifact: snapshot entry {i}: {e}"))?,
            );
        }
        Ok(CacheSnapshot { capacity, entries })
    }
}

/// `<k0..k5:hex> <total:hexbits> <var>*` where each var is
/// `name|cid|rows|cols|brows|bcols|nnz|format|state` (name escaped, so
/// rows split unambiguously on spaces and fields on pipes).
fn encode_entry(e: &ExportedEntry) -> String {
    let mut out = format!(
        "{:016x} {:016x} {:016x} {:016x} {:016x} {:016x} {}",
        e.key[0],
        e.key[1],
        e.key[2],
        e.key[3],
        e.key[4],
        e.key[5],
        f64_to_hex(e.total)
    );
    for (name, cid, info) in &e.vars {
        let state = match info.state {
            DataState::Hdfs => "h",
            DataState::Mem => "m",
        };
        out.push_str(&format!(
            " {}|{}|{}|{}|{}|{}|{}|{}|{}",
            escape(name),
            cid,
            info.mc.rows,
            info.mc.cols,
            info.mc.brows,
            info.mc.bcols,
            info.mc.nnz,
            info.format.name(),
            state
        ));
    }
    out
}

fn decode_entry(row: &str) -> Result<ExportedEntry, String> {
    let mut parts = row.split(' ');
    let mut key = [0u64; 6];
    for (i, slot) in key.iter_mut().enumerate() {
        let tok = parts.next().ok_or_else(|| format!("missing key word {i}"))?;
        *slot = u64::from_str_radix(tok, 16)
            .map_err(|e| format!("bad key word {i} '{tok}': {e}"))?;
    }
    let total_tok = parts.next().ok_or_else(|| "missing total".to_string())?;
    let total = f64_from_hex(total_tok)?;
    let mut vars = Vec::new();
    for var in parts {
        let fields: Vec<&str> = var.split('|').collect();
        if fields.len() != 9 {
            return Err(format!("var row has {} fields, expected 9: '{var}'", fields.len()));
        }
        let name = unescape(fields[0])?;
        let cid: usize =
            fields[1].parse().map_err(|e| format!("bad var id '{}': {e}", fields[1]))?;
        let int = |s: &str| -> Result<i64, String> {
            s.parse().map_err(|e| format!("bad dimension '{s}': {e}"))
        };
        let mc = MatrixCharacteristics {
            rows: int(fields[2])?,
            cols: int(fields[3])?,
            brows: int(fields[4])?,
            bcols: int(fields[5])?,
            nnz: int(fields[6])?,
        };
        let format = Format::parse(fields[7])
            .ok_or_else(|| format!("unknown format '{}'", fields[7]))?;
        let state = match fields[8] {
            "h" => DataState::Hdfs,
            "m" => DataState::Mem,
            other => return Err(format!("unknown data state '{other}'")),
        };
        vars.push((name, cid, DataInfo { mc, format, state }));
    }
    Ok(ExportedEntry { key, total, vars })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_entry() -> ExportedEntry {
        ExportedEntry {
            key: [1, 2, 3, 4, 5, 6],
            total: 12.75,
            vars: vec![
                (
                    "X files".to_string(), // space exercises escaping
                    0,
                    DataInfo {
                        mc: MatrixCharacteristics::dense(100, 10, 1000),
                        format: Format::BinaryBlock,
                        state: DataState::Hdfs,
                    },
                ),
                (
                    "y".to_string(),
                    1,
                    DataInfo {
                        mc: MatrixCharacteristics { rows: -1, cols: 1, brows: 1000, bcols: 1000, nnz: -1 },
                        format: Format::TextCell,
                        state: DataState::Mem,
                    },
                ),
            ],
        }
    }

    #[test]
    fn entry_codec_round_trips() {
        let e = sample_entry();
        let back = decode_entry(&encode_entry(&e)).unwrap();
        assert_eq!(back.key, e.key);
        assert_eq!(back.total.to_bits(), e.total.to_bits());
        assert_eq!(back.vars.len(), 2);
        assert_eq!(back.vars[0].0, "X files");
        assert_eq!(back.vars[1].2.mc.rows, -1);
    }

    #[test]
    fn snapshot_text_round_trips() {
        let snap = CacheSnapshot { capacity: 4096, entries: vec![sample_entry()] };
        let text = snap.encode();
        let back = CacheSnapshot::decode(&text).unwrap();
        assert_eq!(back.capacity(), 4096);
        assert_eq!(back.len(), 1);
        assert_eq!(back.entries[0].total.to_bits(), 12.75f64.to_bits());
    }

    #[test]
    fn declared_count_mismatch_is_a_diagnostic() {
        let snap = CacheSnapshot { capacity: 16, entries: vec![sample_entry()] };
        // drop the entry row but keep (and re-checksum) the declared count
        let mut w = Writer::new(KIND);
        w.section("meta");
        w.put_usize("capacity", 16);
        w.put_usize("entries", 1);
        w.section("entries");
        let text = w.finish();
        let err = CacheSnapshot::decode(&text).unwrap_err();
        assert!(err.contains("declares 1 entries"), "{err}");
        drop(snap);
    }

    #[test]
    fn malformed_rows_are_diagnostics() {
        assert!(decode_entry("1 2 3").is_err()); // too few key words
        assert!(decode_entry("1 2 3 4 5 6").is_err()); // missing total
        assert!(decode_entry("z 2 3 4 5 6 0").is_err()); // bad hex
        let e = encode_entry(&sample_entry());
        let chopped = e.rsplit_once('|').unwrap().0;
        assert!(decode_entry(chopped).is_err()); // truncated var row
    }
}
