//! Persisted backend-argmin tables: the serve daemon's terminal-rung
//! lookup table ([`crate::serve`]) as a versioned, checksummed artifact.
//!
//! The daemon's argmin table maps `scenario|script|iters` keys to the
//! backend-argmin decision made for them (best backend, estimated cost,
//! plan statistics). Without persistence the table dies with the
//! process, so a restarted daemon answers its first `cached`-rung
//! requests from a freshly costed default plan instead of the decisions
//! it already made. `repro serve --spill-argmin <path>` spills the table
//! after every insert (atomic tmp+rename via [`super::save`]) and
//! reloads it at boot; reloaded keys answer with `source=persisted`.
//!
//! Like every artifact the table is **regenerate-don't-trust**: rows are
//! stamped with the context they were decided under (cost constants and
//! [`FaultProfile`]), and a daemon booting with a different context
//! discards the rows — silently answering from decisions priced under
//! different constants would be wrong, not stale.

use crate::conf::{CostConstants, FaultProfile};
use crate::rtprog::ExecBackend;

use super::codec::{Reader, Writer};

/// Header kind token for argmin tables.
pub const KIND: &str = "argmin";

/// One persisted backend-argmin decision.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgminRow {
    /// Table key: `scenario|script|iters`.
    pub key: String,
    /// The winning backend.
    pub backend: ExecBackend,
    /// Estimated execution time of the winning plan, seconds.
    pub cost_secs: f64,
    /// CP instruction count of the winning plan.
    pub cp: usize,
    /// MR-job count of the winning plan.
    pub mr: usize,
    /// Spark-job count of the winning plan.
    pub spark: usize,
}

/// A persisted argmin table: the decision rows plus the costing context
/// they were decided under.
#[derive(Clone, Debug)]
pub struct ArgminTable {
    /// Cost constants the decisions were priced with.
    pub constants: CostConstants,
    /// Failure profile the decisions were priced with.
    pub fault: FaultProfile,
    /// Decision rows, sorted by key (so the encoding — and therefore the
    /// on-disk artifact — is deterministic regardless of insert order).
    pub rows: Vec<ArgminRow>,
}

impl ArgminTable {
    /// Build a table over the given rows; rows are sorted by key.
    pub fn new(constants: CostConstants, fault: FaultProfile, mut rows: Vec<ArgminRow>) -> Self {
        rows.sort_by(|a, b| a.key.cmp(&b.key));
        ArgminTable { constants, fault, rows }
    }

    /// Whether a loaded table's context matches the booting daemon's —
    /// rows priced under different constants or a different failure
    /// profile must be regenerated, never trusted.
    pub fn context_matches(&self, constants: &CostConstants, fault: &FaultProfile) -> bool {
        self.constants == *constants && self.fault == *fault
    }

    /// Serialize to the artifact text form.
    pub fn encode(&self) -> String {
        let mut w = Writer::new(KIND);
        w.section("context");
        super::put_constants(&mut w, "constants", &self.constants);
        super::put_fault(&mut w, "fault", &self.fault);
        w.section("rows");
        w.put_usize("n", self.rows.len());
        for (i, row) in self.rows.iter().enumerate() {
            w.put_str(&format!("row.{i}.key"), &row.key);
            w.put_str(&format!("row.{i}.backend"), row.backend.name());
            w.put_f64(&format!("row.{i}.cost_secs"), row.cost_secs);
            w.put_usize(&format!("row.{i}.cp"), row.cp);
            w.put_usize(&format!("row.{i}.mr"), row.mr);
            w.put_usize(&format!("row.{i}.spark"), row.spark);
        }
        w.finish()
    }

    /// Parse from the artifact text form.
    pub fn decode(text: &str) -> Result<Self, String> {
        let reader = Reader::parse(text)?;
        if reader.kind() != KIND {
            return Err(format!(
                "artifact: expected a '{KIND}' artifact, got '{}'",
                reader.kind()
            ));
        }
        Self::decode_from(&reader)
    }

    pub(crate) fn decode_from(reader: &Reader) -> Result<Self, String> {
        let ctx = reader.section("context")?;
        let constants = super::get_constants(&ctx, "constants")?;
        let fault = super::get_fault(&ctx, "fault")?;
        fault
            .validate()
            .map_err(|e| format!("artifact: argmin table carries an unusable profile: {e}"))?;
        let rows_s = reader.section("rows")?;
        let n = rows_s.usize("n")?;
        let mut rows = Vec::with_capacity(n);
        for i in 0..n {
            let name = rows_s.str(&format!("row.{i}.backend"))?;
            let backend = ExecBackend::parse(&name)
                .ok_or_else(|| format!("artifact: unknown backend '{name}' in argmin row {i}"))?;
            let cost_secs = rows_s.f64(&format!("row.{i}.cost_secs"))?;
            if !cost_secs.is_finite() {
                return Err(format!(
                    "artifact: non-finite cost {cost_secs} in argmin row {i}"
                ));
            }
            rows.push(ArgminRow {
                key: rows_s.str(&format!("row.{i}.key"))?,
                backend,
                cost_secs,
                cp: rows_s.usize(&format!("row.{i}.cp"))?,
                mr: rows_s.usize(&format!("row.{i}.mr"))?,
                spark: rows_s.usize(&format!("row.{i}.spark"))?,
            });
        }
        Ok(ArgminTable::new(constants, fault, rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ArgminTable {
        ArgminTable::new(
            CostConstants::default(),
            FaultProfile::chaos(),
            vec![
                ArgminRow {
                    key: "XL1|cg|10".to_string(),
                    backend: ExecBackend::Cp,
                    cost_secs: 1234.5,
                    cp: 91,
                    mr: 0,
                    spark: 0,
                },
                ArgminRow {
                    key: "XS|ds|0".to_string(),
                    backend: ExecBackend::Cp,
                    cost_secs: 2.25,
                    cp: 17,
                    mr: 0,
                    spark: 0,
                },
            ],
        )
    }

    #[test]
    fn argmin_table_round_trips_bitwise() {
        let t = sample();
        let text = t.encode();
        let back = ArgminTable::decode(&text).unwrap();
        assert_eq!(back.constants, t.constants);
        assert_eq!(back.fault, t.fault);
        assert_eq!(back.rows.len(), t.rows.len());
        for (a, b) in back.rows.iter().zip(&t.rows) {
            assert_eq!(a.key, b.key);
            assert_eq!(a.backend, b.backend);
            assert_eq!(a.cost_secs.to_bits(), b.cost_secs.to_bits());
            assert_eq!((a.cp, a.mr, a.spark), (b.cp, b.mr, b.spark));
        }
        assert_eq!(back.encode(), text);
    }

    #[test]
    fn rows_are_sorted_regardless_of_insert_order() {
        let mut t = sample();
        t.rows.reverse();
        let resorted = ArgminTable::new(t.constants.clone(), t.fault.clone(), t.rows.clone());
        assert_eq!(resorted.encode(), sample().encode());
    }

    #[test]
    fn context_mismatch_is_detected() {
        let t = sample();
        assert!(t.context_matches(&CostConstants::default(), &FaultProfile::chaos()));
        assert!(!t.context_matches(&CostConstants::default(), &FaultProfile::none()));
        let mut k = CostConstants::default();
        k.mem_bw *= 2.0;
        assert!(!t.context_matches(&k, &FaultProfile::chaos()));
    }

    #[test]
    fn corrupt_rows_are_diagnostics() {
        let mut t = sample();
        t.rows[0].cost_secs = f64::NAN;
        let err = ArgminTable::decode(&t.encode()).unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
        // wrong-kind header
        let w = Writer::new("profile");
        let err = ArgminTable::decode(&w.finish()).unwrap_err();
        assert!(err.contains("expected a 'argmin'"), "{err}");
    }

    #[test]
    fn degenerate_profile_is_rejected_at_load() {
        let mut t = sample();
        t.fault.max_attempts = 0;
        let err = ArgminTable::decode(&t.encode()).unwrap_err();
        assert!(err.contains("unusable profile"), "{err}");
    }
}
