//! Plan artifacts: a compiled runtime plan persisted as a **stable**
//! section (everything needed to regenerate the plan — DML script, `$N`
//! args, input metadata, cluster/system/cost configuration, selection
//! hints) plus a **synthesized** section (the structural root hash from
//! [`crate::cost::cache::program_hashes`], per-block costs, the total
//! cost and the runtime EXPLAIN).
//!
//! The split follows the Regorus RVM `Program` artifact: the synthesized
//! half is a *cache*, not a source of truth. [`PlanArtifact::load_checked`]
//! always recompiles the stable section and compares (a) the payload
//! format version and (b) the 128-bit structural root hash against the
//! stored synthesized section — on any mismatch the synthesized section
//! is regenerated from the stable one (and the load reports why), never
//! trusted stale and never a hard error.

use std::collections::HashMap;

use crate::api::{compile_with_meta, ClusterConfigOpt, CompileOptions, CompiledProgram};
use crate::conf::{ClusterConfig, CostConstants, SystemConfig};
use crate::cost::cache::{program_hashes, ProgramHashes};
use crate::cost::cost_program;
use crate::ir::build::StaticMeta;
use crate::lop::SelectionHints;
use crate::matrix::{Format, MatrixCharacteristics};
use crate::rtprog::ExecBackend;

use super::codec::{f64_to_hex, Reader, Writer};

/// Header kind token for plan artifacts.
pub const KIND: &str = "plan";

/// Version of the *synthesized payload* layout. Stored in the stable
/// section; a loaded artifact whose stored version differs has its
/// synthesized section regenerated from the stable section.
pub const PLAN_FORMAT_VERSION: u32 = 1;

/// One persistent-read input: abstract path plus the static metadata the
/// compiler sees (the [`StaticMeta`] entry, flattened).
#[derive(Clone, Debug, PartialEq)]
pub struct PlanInput {
    /// Abstract input path the script `read()`s.
    pub path: String,
    /// Size metadata (dims, blocking, nnz).
    pub mc: MatrixCharacteristics,
    /// On-disk format.
    pub format: Format,
}

/// A compiled plan as stored on disk (stable + synthesized sections).
#[derive(Clone, Debug)]
pub struct PlanArtifact {
    // ----- stable section -----
    /// DML script source text.
    pub script: String,
    /// `$N` argument bindings, sorted by position.
    pub args: Vec<(usize, String)>,
    /// Input metadata (sorted by path).
    pub inputs: Vec<PlanInput>,
    /// Default execution backend.
    pub backend: ExecBackend,
    /// Compiler/system configuration.
    pub cfg: SystemConfig,
    /// Cluster characteristics `cc`.
    pub cc: ClusterConfig,
    /// Physical-operator selection hints.
    pub hints: SelectionHints,
    /// Cost constants the synthesized costs were computed under.
    pub constants: CostConstants,
    /// Payload version the synthesized section was written with.
    pub synth_version: u32,
    // ----- synthesized section -----
    /// 128-bit structural root hash of the generated runtime program.
    pub root: (u64, u64),
    /// Estimated total cost `C(P, cc)` in seconds (bitwise-exact).
    pub total: f64,
    /// Per-top-level-block structural hash and cost.
    pub blocks: Vec<((u64, u64), f64)>,
    /// Plan size: CP instructions, MR jobs, Spark jobs.
    pub size: (usize, usize, usize),
    /// Runtime EXPLAIN of the generated plan.
    pub explain: String,
}

/// The result of loading (and validating) a plan artifact: the artifact
/// with a trustworthy synthesized section, plus the freshly compiled
/// program it was validated against.
#[derive(Clone, Debug)]
pub struct LoadedPlan {
    /// The artifact; its synthesized section has been regenerated if the
    /// stored one was stale.
    pub artifact: PlanArtifact,
    /// The program recompiled from the stable section.
    pub compiled: CompiledProgram,
    /// Structural hashes of `compiled` (reusable for cached costing).
    pub hashes: ProgramHashes,
    /// Whether the synthesized section was regenerated on load.
    pub regenerated: bool,
    /// Why it was regenerated (version or hash mismatch), if it was.
    pub reason: Option<String>,
    /// The EXPLAIN text as stored on disk (before any regeneration),
    /// kept for diffing against the fresh plan.
    pub stored_explain: String,
}

impl LoadedPlan {
    /// LCS diff between the stored EXPLAIN and the freshly compiled one.
    /// All-context (no `-`/`+` lines) means the plans are identical.
    pub fn explain_diff(&self) -> String {
        crate::opt::gdf::line_diff(&self.stored_explain, &self.artifact.explain)
    }

    /// Whether the stored and fresh EXPLAINs are line-identical.
    pub fn plan_unchanged(&self) -> bool {
        self.stored_explain == self.artifact.explain
    }
}

impl PlanArtifact {
    /// Compile `script` and capture both sections of a plan artifact.
    pub fn capture(
        script: &str,
        args: &HashMap<usize, String>,
        meta: &StaticMeta,
        opts: &CompileOptions,
        constants: &CostConstants,
    ) -> Result<PlanArtifact, String> {
        let mut args: Vec<(usize, String)> =
            args.iter().map(|(&n, v)| (n, v.clone())).collect();
        args.sort_unstable_by_key(|(n, _)| *n);
        let mut inputs: Vec<PlanInput> = meta
            .0
            .iter()
            .map(|(path, &(mc, format))| PlanInput { path: path.clone(), mc, format })
            .collect();
        inputs.sort_unstable_by(|a, b| a.path.cmp(&b.path));
        let mut art = PlanArtifact {
            script: script.to_string(),
            args,
            inputs,
            backend: opts.backend,
            cfg: opts.cfg.clone(),
            cc: opts.cc.0.clone(),
            hints: opts.hints.clone(),
            constants: constants.clone(),
            synth_version: PLAN_FORMAT_VERSION,
            root: (0, 0),
            total: 0.0,
            blocks: Vec::new(),
            size: (0, 0, 0),
            explain: String::new(),
        };
        let (compiled, hashes) = art.recompile()?;
        art.resynthesize(&compiled, &hashes);
        Ok(art)
    }

    /// Recompile the stable section into a runtime program (the
    /// synthesized section is ignored — this is the regeneration path).
    pub fn recompile(&self) -> Result<(CompiledProgram, ProgramHashes), String> {
        let args: HashMap<usize, String> = self.args.iter().cloned().collect();
        let mut meta = StaticMeta::default();
        for input in &self.inputs {
            meta = meta.with(&input.path, input.mc, input.format);
        }
        let opts = CompileOptions {
            cfg: self.cfg.clone(),
            cc: ClusterConfigOpt(self.cc.clone()),
            hints: self.hints.clone(),
            backend: self.backend,
        };
        let compiled = compile_with_meta(&self.script, &args, &meta, &opts)?;
        let hashes = program_hashes(&compiled.runtime);
        Ok((compiled, hashes))
    }

    /// Overwrite the synthesized section from a freshly compiled program.
    fn resynthesize(&mut self, compiled: &CompiledProgram, hashes: &ProgramHashes) {
        let report = cost_program(&compiled.runtime, &self.cfg, &self.cc, &self.constants);
        self.root = hashes.root();
        self.total = report.total;
        self.blocks = hashes
            .block_roots()
            .into_iter()
            .zip(report.nodes.iter().map(|n| n.total()))
            .collect();
        self.size = compiled.runtime.size3();
        self.explain = compiled.explain_runtime();
        self.synth_version = PLAN_FORMAT_VERSION;
    }

    /// Validate the synthesized section against a fresh compile of the
    /// stable section, regenerating it on a payload-version or
    /// structural-hash mismatch. This is *the* way to consume a plan
    /// artifact: the result's synthesized data is always trustworthy.
    pub fn load_checked(mut self) -> Result<LoadedPlan, String> {
        let (compiled, hashes) = self.recompile()?;
        let stored_explain = self.explain.clone();
        let reason = if self.synth_version != PLAN_FORMAT_VERSION {
            Some(format!(
                "synthesized payload version v{} != current v{PLAN_FORMAT_VERSION}",
                self.synth_version
            ))
        } else if self.root != hashes.root() {
            Some(format!(
                "structural hash mismatch: stored {:016x}{:016x}, recompiled {:016x}{:016x}",
                self.root.0,
                self.root.1,
                hashes.root().0,
                hashes.root().1
            ))
        } else {
            None
        };
        let regenerated = reason.is_some();
        if regenerated {
            self.resynthesize(&compiled, &hashes);
        }
        Ok(LoadedPlan { artifact: self, compiled, hashes, regenerated, reason, stored_explain })
    }

    /// One-paragraph human summary (used by `repro plan load`).
    pub fn describe(&self) -> String {
        let (cp, mr, sp) = self.size;
        format!(
            "plan: backend={} blocks={} size CP/MR/SPARK={}/{}/{} total={:.3}s root={:016x}{:016x} inputs={}",
            self.backend.name(),
            self.blocks.len(),
            cp,
            mr,
            sp,
            self.total,
            self.root.0,
            self.root.1,
            self.inputs.len()
        )
    }

    /// Serialize to the artifact text form.
    pub fn encode(&self) -> String {
        let mut w = Writer::new(KIND);
        w.section("stable");
        w.put_u64("synth_version", self.synth_version as u64);
        w.put_str("script", &self.script);
        w.put_raw("backend", self.backend.name());
        for (n, v) in &self.args {
            w.put_str(&format!("arg.{n}"), v);
            w.put_u64("arg", *n as u64);
        }
        for input in &self.inputs {
            let mc = &input.mc;
            w.put_raw(
                "input",
                &format!(
                    "{}|{}|{}|{}|{}|{}|{}",
                    super::codec::escape(&input.path),
                    mc.rows,
                    mc.cols,
                    mc.brows,
                    mc.bcols,
                    mc.nnz,
                    input.format.name()
                ),
            );
        }
        w.put_bool("hints.force_cpmm", self.hints.force_cpmm);
        w.put_bool("hints.force_rmm", self.hints.force_rmm);
        w.put_bool("hints.no_transpose_rewrite", self.hints.no_transpose_rewrite);
        super::put_sysconf(&mut w, "cfg", &self.cfg);
        super::put_cluster(&mut w, "cc", &self.cc);
        super::put_constants(&mut w, "k", &self.constants);
        w.section("synthesized");
        w.put_raw("root", &format!("{:016x} {:016x}", self.root.0, self.root.1));
        w.put_f64("total", self.total);
        w.put_usize("size.cp", self.size.0);
        w.put_usize("size.mr", self.size.1);
        w.put_usize("size.spark", self.size.2);
        for ((h1, h2), cost) in &self.blocks {
            w.put_raw("block", &format!("{h1:016x} {h2:016x} {}", f64_to_hex(*cost)));
        }
        w.put_str("explain", &self.explain);
        w.finish()
    }

    /// Parse from the artifact text form.
    pub fn decode(text: &str) -> Result<Self, String> {
        let reader = Reader::parse(text)?;
        if reader.kind() != KIND {
            return Err(format!("artifact: expected a '{KIND}' artifact, got '{}'", reader.kind()));
        }
        Self::decode_from(&reader)
    }

    pub(crate) fn decode_from(reader: &Reader) -> Result<Self, String> {
        let stable = reader.section("stable")?;
        let synth_version = stable.u64("synth_version")? as u32;
        let script = stable.str("script")?;
        let backend_name = stable.get("backend")?;
        let backend = ExecBackend::parse(backend_name)
            .ok_or_else(|| format!("artifact: unknown backend '{backend_name}'"))?;
        let mut args = Vec::new();
        for n_raw in stable.get_all("arg") {
            let n: usize = n_raw
                .parse()
                .map_err(|e| format!("artifact: bad arg position '{n_raw}': {e}"))?;
            args.push((n, stable.str(&format!("arg.{n}"))?));
        }
        let mut inputs = Vec::new();
        for row in stable.get_all("input") {
            let fields: Vec<&str> = row.split('|').collect();
            if fields.len() != 7 {
                return Err(format!(
                    "artifact: input row has {} fields, expected 7: '{row}'",
                    fields.len()
                ));
            }
            let int = |s: &str| -> Result<i64, String> {
                s.parse().map_err(|e| format!("artifact: bad input dimension '{s}': {e}"))
            };
            inputs.push(PlanInput {
                path: super::codec::unescape(fields[0])?,
                mc: MatrixCharacteristics {
                    rows: int(fields[1])?,
                    cols: int(fields[2])?,
                    brows: int(fields[3])?,
                    bcols: int(fields[4])?,
                    nnz: int(fields[5])?,
                },
                format: Format::parse(fields[6])
                    .ok_or_else(|| format!("artifact: unknown input format '{}'", fields[6]))?,
            });
        }
        let hints = SelectionHints {
            force_cpmm: stable.bool("hints.force_cpmm")?,
            force_rmm: stable.bool("hints.force_rmm")?,
            no_transpose_rewrite: stable.bool("hints.no_transpose_rewrite")?,
        };
        let cfg = super::get_sysconf(&stable, "cfg")?;
        let cc = super::get_cluster(&stable, "cc")?;
        let constants = super::get_constants(&stable, "k")?;

        let synth = reader.section("synthesized")?;
        let root_raw = synth.get("root")?;
        let root = parse_hash_pair(root_raw)
            .ok_or_else(|| format!("artifact: bad root hash '{root_raw}'"))?;
        let total = synth.f64("total")?;
        let size = (synth.usize("size.cp")?, synth.usize("size.mr")?, synth.usize("size.spark")?);
        let mut blocks = Vec::new();
        for row in synth.get_all("block") {
            let mut parts = row.split(' ');
            let pair = match (parts.next(), parts.next(), parts.next(), parts.next()) {
                (Some(h1), Some(h2), Some(cost), None) => {
                    let hash = parse_hash_pair(&format!("{h1} {h2}"));
                    let cost = super::codec::f64_from_hex(cost).ok();
                    hash.zip(cost)
                }
                _ => None,
            };
            let (hash, cost) =
                pair.ok_or_else(|| format!("artifact: bad block row '{row}'"))?;
            blocks.push((hash, cost));
        }
        let explain = synth.str("explain")?;

        Ok(PlanArtifact {
            script,
            args,
            inputs,
            backend,
            cfg,
            cc,
            hints,
            constants,
            synth_version,
            root,
            total,
            blocks,
            size,
            explain,
        })
    }
}

fn parse_hash_pair(s: &str) -> Option<(u64, u64)> {
    let (a, b) = s.split_once(' ')?;
    Some((u64::from_str_radix(a, 16).ok()?, u64::from_str_radix(b, 16).ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Scenario;

    fn xs_artifact() -> PlanArtifact {
        let s = Scenario::xs();
        let opts = CompileOptions::default();
        PlanArtifact::capture(
            s.script(),
            &s.args(),
            &s.meta(opts.cfg.blocksize),
            &opts,
            &CostConstants::default(),
        )
        .unwrap()
    }

    #[test]
    fn capture_encode_decode_is_identity() {
        let art = xs_artifact();
        assert!(art.total > 0.0);
        assert!(!art.blocks.is_empty());
        let text = art.encode();
        let back = PlanArtifact::decode(&text).unwrap();
        assert_eq!(back.script, art.script);
        assert_eq!(back.args, art.args);
        assert_eq!(back.inputs, art.inputs);
        assert_eq!(back.backend, art.backend);
        assert_eq!(back.cfg, art.cfg);
        assert_eq!(back.cc, art.cc);
        assert_eq!(back.constants, art.constants);
        assert_eq!(back.root, art.root);
        assert_eq!(back.total.to_bits(), art.total.to_bits());
        assert_eq!(back.blocks, art.blocks);
        assert_eq!(back.size, art.size);
        assert_eq!(back.explain, art.explain);
        // and the re-encode is byte-identical (stable output order)
        assert_eq!(back.encode(), text);
    }

    #[test]
    fn fresh_artifact_loads_without_regeneration() {
        let loaded = xs_artifact().load_checked().unwrap();
        assert!(!loaded.regenerated);
        assert!(loaded.reason.is_none());
        assert!(loaded.plan_unchanged());
        assert!(loaded.explain_diff().lines().all(|l| l.starts_with("  ")));
    }

    #[test]
    fn version_mismatch_regenerates_synthesized() {
        let mut art = xs_artifact();
        let true_total = art.total;
        art.synth_version = 999;
        art.total = -1.0; // poisoned synthesized data must not survive
        let loaded = art.load_checked().unwrap();
        assert!(loaded.regenerated);
        assert!(loaded.reason.as_ref().unwrap().contains("version"), "{:?}", loaded.reason);
        assert_eq!(loaded.artifact.total.to_bits(), true_total.to_bits());
        assert_eq!(loaded.artifact.synth_version, PLAN_FORMAT_VERSION);
    }

    #[test]
    fn structural_hash_mismatch_regenerates_synthesized() {
        let mut art = xs_artifact();
        let true_root = art.root;
        art.root = (0xdead, 0xbeef);
        art.explain = "STALE".to_string();
        let loaded = art.load_checked().unwrap();
        assert!(loaded.regenerated);
        assert!(loaded.reason.as_ref().unwrap().contains("hash mismatch"), "{:?}", loaded.reason);
        assert_eq!(loaded.artifact.root, true_root);
        assert_ne!(loaded.artifact.explain, "STALE");
        assert_eq!(loaded.stored_explain, "STALE");
        assert!(!loaded.plan_unchanged());
    }

    #[test]
    fn stable_edit_changes_hash_and_triggers_regeneration() {
        // edit the stable section only (bigger input): the stored root no
        // longer matches what the stable section compiles to
        let mut art = xs_artifact();
        for input in &mut art.inputs {
            if input.path == "data/X" {
                input.mc = MatrixCharacteristics::dense(100_000_000, 1000, 1000);
            }
        }
        let loaded = art.load_checked().unwrap();
        assert!(loaded.regenerated);
        assert!(loaded.artifact.size.1 > 0, "XL-sized input must distribute");
    }

    #[test]
    fn synthesized_total_matches_cost_program_bitwise() {
        let art = xs_artifact();
        let (compiled, _) = art.recompile().unwrap();
        let report = cost_program(&compiled.runtime, &art.cfg, &art.cc, &art.constants);
        assert_eq!(report.total.to_bits(), art.total.to_bits());
        let block_sum: f64 = art.blocks.iter().map(|(_, c)| c).sum();
        assert!((block_sum - art.total).abs() < 1e-9 * art.total.max(1.0));
    }
}
