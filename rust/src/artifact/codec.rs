//! Zero-dependency versioned text codec for on-disk artifacts.
//!
//! The container format is deliberately line-oriented UTF-8 so artifacts
//! diff cleanly under version control and corruption is diagnosable by
//! eye:
//!
//! ```text
//! #! sysds-artifact v1 <kind>
//! [section]
//! key = value
//! ...
//! #! checksum <16-hex FNV-1a of everything above>
//! ```
//!
//! Three rules make the format safe to round-trip:
//!
//! 1. **Everything is escaped.** Values pass through [`escape`], which
//!    folds backslash, newline, carriage return, `|` and space into
//!    two-character sequences — so every `key = value` line is exactly
//!    one line, and packed rows (cache entries) can split on spaces and
//!    pipes without quoting ambiguity.
//! 2. **`f64` round-trips bitwise.** Floats are stored as the 16-hex-digit
//!    IEEE-754 bit pattern ([`put_f64`]/[`Section::f64`]), never as
//!    decimal text, because the cost-cache replay contract is *bitwise*
//!    equality — a `%.17g` detour would be one rounding away from a
//!    silently different ranking.
//! 3. **The trailing checksum detects truncation.** [`Reader::parse`]
//!    refuses input whose FNV-1a checksum line is missing or mismatched,
//!    with a diagnostic instead of a panic, so a partially written or
//!    bit-flipped artifact can never be half-loaded.
//!
//! The container version (`v1`) covers this framing only; each artifact
//! kind carries its own payload version inside a section, which is what
//! the regenerate-on-mismatch rules key off (see
//! [`super::plan::PLAN_FORMAT_VERSION`]).

use std::fmt::Write as _;

/// Version of the container framing (header/sections/checksum). Bumped
/// only if the framing itself changes; payload evolution is versioned
/// per artifact kind.
pub const CONTAINER_VERSION: u32 = 1;

const MAGIC: &str = "#! sysds-artifact";
const CHECKSUM_PREFIX: &str = "#! checksum ";

// FNV-1a 64-bit, the same function backing the cost-cache keys.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Escape a string into a single space-free, pipe-free token:
/// `\` → `\\`, newline → `\n`, CR → `\r`, `|` → `\p`, space → `\s`.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '|' => out.push_str("\\p"),
            ' ' => out.push_str("\\s"),
            _ => out.push(c),
        }
    }
    out
}

/// Invert [`escape`]. Unknown escape sequences are a diagnostic (they
/// mean the file was produced by a newer writer or corrupted).
pub fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('\\') => out.push('\\'),
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('p') => out.push('|'),
            Some('s') => out.push(' '),
            other => {
                return Err(format!(
                    "artifact: bad escape sequence '\\{}' in '{s}'",
                    other.map(String::from).unwrap_or_else(|| "<end>".into())
                ))
            }
        }
    }
    Ok(out)
}

/// Encode an `f64` as its 16-hex-digit IEEE-754 bit pattern.
pub fn f64_to_hex(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

/// Decode [`f64_to_hex`] output back to the bitwise-identical `f64`.
pub fn f64_from_hex(s: &str) -> Result<f64, String> {
    u64::from_str_radix(s.trim(), 16)
        .map(f64::from_bits)
        .map_err(|e| format!("artifact: bad f64 bit pattern '{s}': {e}"))
}

// ---------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------

/// Streaming artifact writer: header, then sections of `key = value`
/// lines, closed by [`Writer::finish`] which appends the checksum.
pub struct Writer {
    buf: String,
}

impl Writer {
    /// Start an artifact of the given kind (`plan`, `costcache`,
    /// `profile`).
    pub fn new(kind: &str) -> Self {
        Writer { buf: format!("{MAGIC} v{CONTAINER_VERSION} {kind}\n") }
    }

    /// Open a `[name]` section; subsequent puts land in it.
    pub fn section(&mut self, name: &str) {
        let _ = writeln!(self.buf, "[{name}]");
    }

    /// Write one raw (pre-escaped or escape-free) `key = value` line.
    pub fn put_raw(&mut self, key: &str, value: &str) {
        debug_assert!(!value.contains('\n'), "raw values must be single-line");
        let _ = writeln!(self.buf, "{key} = {value}");
    }

    /// Write a string value, escaped.
    pub fn put_str(&mut self, key: &str, value: &str) {
        let escaped = escape(value);
        self.put_raw(key, &escaped);
    }

    /// Write an `f64` as its bit pattern (bitwise round trip).
    pub fn put_f64(&mut self, key: &str, value: f64) {
        let hex = f64_to_hex(value);
        self.put_raw(key, &hex);
    }

    /// Write an unsigned integer.
    pub fn put_u64(&mut self, key: &str, value: u64) {
        let dec = value.to_string();
        self.put_raw(key, &dec);
    }

    /// Write a `usize`.
    pub fn put_usize(&mut self, key: &str, value: usize) {
        self.put_u64(key, value as u64);
    }

    /// Write a signed integer.
    pub fn put_i64(&mut self, key: &str, value: i64) {
        let dec = value.to_string();
        self.put_raw(key, &dec);
    }

    /// Write a boolean (`true`/`false`).
    pub fn put_bool(&mut self, key: &str, value: bool) {
        self.put_raw(key, if value { "true" } else { "false" });
    }

    /// Close the artifact: append the checksum line and return the text.
    pub fn finish(self) -> String {
        let sum = fnv1a(self.buf.as_bytes());
        format!("{}{CHECKSUM_PREFIX}{sum:016x}\n", self.buf)
    }
}

// ---------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------

/// Parsed artifact: kind plus ordered sections of ordered `key = value`
/// pairs (repeated keys are allowed and preserve order — that is how
/// lists are encoded).
pub struct Reader {
    kind: String,
    sections: Vec<(String, Vec<(String, String)>)>,
}

impl Reader {
    /// Parse and verify an artifact: header magic, container version and
    /// trailing checksum. Every failure is a diagnostic `Err`, never a
    /// panic — corrupted, truncated and wrong-kind files all land here.
    pub fn parse(text: &str) -> Result<Reader, String> {
        // 1. split off and verify the checksum line
        let body_end = text
            .rfind(CHECKSUM_PREFIX)
            .ok_or_else(|| "artifact: missing checksum line (truncated file?)".to_string())?;
        let (body, sum_line) = text.split_at(body_end);
        let sum_hex = sum_line
            .trim_start_matches(CHECKSUM_PREFIX)
            .trim();
        let stored = u64::from_str_radix(sum_hex, 16)
            .map_err(|e| format!("artifact: unreadable checksum '{sum_hex}': {e}"))?;
        let actual = fnv1a(body.as_bytes());
        if stored != actual {
            return Err(format!(
                "artifact: checksum mismatch (stored {stored:016x}, computed {actual:016x}) — \
                 the file is corrupted or was edited by hand"
            ));
        }

        // 2. header
        let mut lines = body.lines();
        let header = lines.next().unwrap_or_default();
        let rest = header
            .strip_prefix(MAGIC)
            .ok_or_else(|| format!("artifact: bad header '{header}' (expected '{MAGIC} vN <kind>')"))?;
        let mut parts = rest.split_whitespace();
        let ver = parts.next().unwrap_or_default();
        let kind = parts.next().unwrap_or_default();
        let ver_num: u32 = ver
            .strip_prefix('v')
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| format!("artifact: bad container version '{ver}'"))?;
        if ver_num != CONTAINER_VERSION {
            return Err(format!(
                "artifact: unsupported container version v{ver_num} (this build reads v{CONTAINER_VERSION})"
            ));
        }
        if kind.is_empty() {
            return Err("artifact: header is missing the artifact kind".to_string());
        }

        // 3. sections
        let mut sections: Vec<(String, Vec<(String, String)>)> = Vec::new();
        for (n, line) in lines.enumerate() {
            let line_no = n + 2; // 1-based, after the header
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                sections.push((name.to_string(), Vec::new()));
                continue;
            }
            let (key, value) = line.split_once(" = ").ok_or_else(|| {
                format!("artifact: line {line_no}: expected 'key = value' or '[section]', got '{line}'")
            })?;
            match sections.last_mut() {
                Some((_, entries)) => entries.push((key.to_string(), value.to_string())),
                None => {
                    return Err(format!(
                        "artifact: line {line_no}: 'key = value' before any [section]"
                    ))
                }
            }
        }
        Ok(Reader { kind: kind.to_string(), sections })
    }

    /// The artifact kind token from the header.
    pub fn kind(&self) -> &str {
        &self.kind
    }

    /// Look up a section by name.
    pub fn section(&self, name: &str) -> Result<Section<'_>, String> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, entries)| Section { name, entries })
            .ok_or_else(|| format!("artifact: missing [{name}] section"))
    }

    /// Whether a section exists (the plan loader uses this to distinguish
    /// "no synthesized section" from "unreadable synthesized section").
    pub fn has_section(&self, name: &str) -> bool {
        self.sections.iter().any(|(n, _)| n == name)
    }
}

/// One parsed `[section]`: ordered key/value pairs with typed accessors.
pub struct Section<'a> {
    name: &'a str,
    entries: &'a [(String, String)],
}

impl<'a> Section<'a> {
    /// The raw value of a key that must appear exactly once.
    pub fn get(&self, key: &str) -> Result<&'a str, String> {
        let mut found = None;
        for (k, v) in self.entries {
            if k == key {
                if found.is_some() {
                    return Err(format!(
                        "artifact: [{}] has duplicate key '{key}'",
                        self.name
                    ));
                }
                found = Some(v.as_str());
            }
        }
        found.ok_or_else(|| format!("artifact: [{}] is missing key '{key}'", self.name))
    }

    /// Every value of a repeated key, in file order.
    pub fn get_all(&self, key: &str) -> Vec<&'a str> {
        self.entries.iter().filter(|(k, _)| k == key).map(|(_, v)| v.as_str()).collect()
    }

    /// An escaped string value.
    pub fn str(&self, key: &str) -> Result<String, String> {
        unescape(self.get(key)?)
    }

    /// A bit-pattern `f64` value.
    pub fn f64(&self, key: &str) -> Result<f64, String> {
        f64_from_hex(self.get(key)?)
    }

    /// An unsigned integer value.
    pub fn u64(&self, key: &str) -> Result<u64, String> {
        let v = self.get(key)?;
        v.trim()
            .parse()
            .map_err(|e| format!("artifact: [{}] key '{key}': bad integer '{v}': {e}", self.name))
    }

    /// A `usize` value.
    pub fn usize(&self, key: &str) -> Result<usize, String> {
        Ok(self.u64(key)? as usize)
    }

    /// A signed integer value.
    pub fn i64(&self, key: &str) -> Result<i64, String> {
        let v = self.get(key)?;
        v.trim()
            .parse()
            .map_err(|e| format!("artifact: [{}] key '{key}': bad integer '{v}': {e}", self.name))
    }

    /// A boolean value.
    pub fn bool(&self, key: &str) -> Result<bool, String> {
        match self.get(key)? {
            "true" => Ok(true),
            "false" => Ok(false),
            other => Err(format!(
                "artifact: [{}] key '{key}': bad boolean '{other}'",
                self.name
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_round_trips_every_special() {
        let s = "a b\\c|d\ne\rf  |\\";
        let e = escape(s);
        assert!(!e.contains(' ') && !e.contains('|') && !e.contains('\n'));
        assert_eq!(unescape(&e).unwrap(), s);
        assert!(unescape("bad\\q").is_err());
        assert!(unescape("dangling\\").is_err());
    }

    #[test]
    fn f64_bits_round_trip() {
        for v in [0.0, -0.0, 1.5, f64::NAN, f64::INFINITY, 4.7e-9, f64::MIN_POSITIVE] {
            let back = f64_from_hex(&f64_to_hex(v)).unwrap();
            assert_eq!(v.to_bits(), back.to_bits());
        }
        assert!(f64_from_hex("xyz").is_err());
    }

    #[test]
    fn writer_reader_round_trip() {
        let mut w = Writer::new("plan");
        w.section("stable");
        w.put_str("script", "X = read($1);\nwrite(X, $2);");
        w.put_f64("ratio", 0.7);
        w.put_u64("n", 42);
        w.put_bool("quick", true);
        w.section("synth");
        w.put_raw("e", "1 2 3");
        w.put_raw("e", "4 5 6");
        let text = w.finish();

        let r = Reader::parse(&text).unwrap();
        assert_eq!(r.kind(), "plan");
        let s = r.section("stable").unwrap();
        assert_eq!(s.str("script").unwrap(), "X = read($1);\nwrite(X, $2);");
        assert_eq!(s.f64("ratio").unwrap(), 0.7);
        assert_eq!(s.u64("n").unwrap(), 42);
        assert!(s.bool("quick").unwrap());
        assert_eq!(r.section("synth").unwrap().get_all("e"), vec!["1 2 3", "4 5 6"]);
        assert!(r.section("missing").is_err());
        assert!(s.get("nope").is_err());
    }

    #[test]
    fn corruption_and_truncation_are_diagnostics() {
        let mut w = Writer::new("costcache");
        w.section("meta");
        w.put_u64("capacity", 1024);
        let text = w.finish();

        // bitwise corruption
        let corrupted = text.replace("1024", "1025");
        let err = Reader::parse(&corrupted).unwrap_err();
        assert!(err.contains("checksum mismatch"), "{err}");

        // truncation (checksum line lost)
        let truncated = &text[..text.len() / 2];
        let err = Reader::parse(truncated).unwrap_err();
        assert!(err.contains("checksum") || err.contains("truncated"), "{err}");

        // truncation mid-body with the checksum line still present
        let half = format!("{}\n#! checksum 0000000000000000\n", &text[..20]);
        assert!(Reader::parse(&half).is_err());

        // wrong container version
        let v2 = text.replace("v1 costcache", "v2 costcache");
        let err = Reader::parse(&v2).unwrap_err();
        assert!(err.contains("checksum") || err.contains("version"), "{err}");

        // not an artifact at all
        assert!(Reader::parse("hello world").is_err());
        assert!(Reader::parse("").is_err());
    }

    #[test]
    fn duplicate_scalar_keys_are_rejected() {
        let mut w = Writer::new("profile");
        w.section("s");
        w.put_u64("seed", 1);
        w.put_u64("seed", 2);
        let text = w.finish();
        let r = Reader::parse(&text).unwrap();
        assert!(r.section("s").unwrap().u64("seed").is_err());
    }
}
