//! Persistent plan artifacts: versioned on-disk plans, cost-cache
//! snapshots and calibration profiles (ROADMAP item 3).
//!
//! Everything the system learns at runtime — compiled plans, the sharded
//! block cost cache, calibrated cost constants — dies with the process.
//! This module serializes all three as self-describing, checksummed text
//! artifacts (see [`codec`] for the container format) so the next
//! process starts warm:
//!
//! * [`PlanArtifact`] — a compiled plan split into a **stable** section
//!   (DML script, `$N` args, input metadata, cluster/system/cost
//!   configuration — everything needed to regenerate the plan) and a
//!   **synthesized** section (the 128-bit structural root hash from
//!   [`crate::cost::cache`], per-block costs, total cost, runtime
//!   EXPLAIN). When the payload format version or the structural hash no
//!   longer matches what the stable section compiles to, the synthesized
//!   section is *regenerated*, never trusted — the Regorus RVM `Program`
//!   artifact split.
//! * [`CacheSnapshot`] — an export of the totals-only entries of a
//!   [`crate::cost::cache::CostCache`], shard-merged back in on load and
//!   replayed bitwise-identically.
//! * [`CalibrationProfile`] — the fitted [`Corrections`] and calibrated
//!   [`CostConstants`] from [`crate::feedback`], stamped with
//!   seed/mode/Q-error so a loaded profile is auditable.
//!
//! The high-level entry points are [`crate::api::save_artifact`] /
//! [`crate::api::load_artifact`] and the `repro plan save|load|diff`
//! CLI plus the `--warm-cache` / `--profile` flags.

pub mod argmin;
pub mod codec;
pub mod plan;
pub mod profile;
pub mod snapshot;

use std::path::Path;

use crate::conf::{ClusterConfig, CostConstants, FaultProfile, SystemConfig};
use crate::feedback::Corrections;
use codec::{Reader, Section, Writer};

pub use argmin::{ArgminRow, ArgminTable};
pub use plan::{LoadedPlan, PlanArtifact, PlanInput, PLAN_FORMAT_VERSION};
pub use profile::CalibrationProfile;
pub use snapshot::CacheSnapshot;

/// One artifact of any kind, as stored on disk.
#[derive(Clone, Debug)]
pub enum Artifact {
    /// A compiled plan (stable + synthesized sections).
    Plan(PlanArtifact),
    /// A cost-cache snapshot.
    CacheSnapshot(CacheSnapshot),
    /// A calibration profile.
    Profile(CalibrationProfile),
    /// A serve-daemon backend-argmin table.
    Argmin(ArgminTable),
}

impl Artifact {
    /// The kind token written into the artifact header.
    pub fn kind(&self) -> &'static str {
        match self {
            Artifact::Plan(_) => plan::KIND,
            Artifact::CacheSnapshot(_) => snapshot::KIND,
            Artifact::Profile(_) => profile::KIND,
            Artifact::Argmin(_) => argmin::KIND,
        }
    }

    /// Serialize to the on-disk text form.
    pub fn encode(&self) -> String {
        match self {
            Artifact::Plan(p) => p.encode(),
            Artifact::CacheSnapshot(s) => s.encode(),
            Artifact::Profile(p) => p.encode(),
            Artifact::Argmin(t) => t.encode(),
        }
    }

    /// Parse any artifact kind, dispatching on the header.
    pub fn decode(text: &str) -> Result<Artifact, String> {
        let reader = Reader::parse(text)?;
        match reader.kind() {
            plan::KIND => Ok(Artifact::Plan(PlanArtifact::decode_from(&reader)?)),
            snapshot::KIND => Ok(Artifact::CacheSnapshot(CacheSnapshot::decode_from(&reader)?)),
            profile::KIND => Ok(Artifact::Profile(CalibrationProfile::decode_from(&reader)?)),
            argmin::KIND => Ok(Artifact::Argmin(ArgminTable::decode_from(&reader)?)),
            other => Err(format!(
                "artifact: unknown kind '{other}' (this build reads '{}', '{}', '{}', '{}')",
                plan::KIND,
                snapshot::KIND,
                profile::KIND,
                argmin::KIND
            )),
        }
    }
}

/// Write an artifact to `path` (atomically: write to `<path>.tmp`, then
/// rename, so a crash never leaves a torn artifact behind).
pub fn save(path: &Path, artifact: &Artifact) -> Result<(), String> {
    let text = artifact.encode();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| format!("artifact: cannot create {}: {e}", parent.display()))?;
        }
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &text)
        .map_err(|e| format!("artifact: cannot write {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("artifact: cannot rename {} -> {}: {e}", tmp.display(), path.display()))
}

/// Read and parse an artifact of any kind from `path`.
pub fn load(path: &Path) -> Result<Artifact, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("artifact: cannot read {}: {e}", path.display()))?;
    Artifact::decode(&text).map_err(|e| format!("{} — in {}", e, path.display()))
}

// ---------------------------------------------------------------------
// Shared configuration (de)serializers — used by plan and profile
// ---------------------------------------------------------------------

pub(crate) fn put_cluster(w: &mut Writer, prefix: &str, cc: &ClusterConfig) {
    w.put_f64(&format!("{prefix}.cp_heap_bytes"), cc.cp_heap_bytes);
    w.put_f64(&format!("{prefix}.map_heap_bytes"), cc.map_heap_bytes);
    w.put_f64(&format!("{prefix}.reduce_heap_bytes"), cc.reduce_heap_bytes);
    w.put_usize(&format!("{prefix}.k_local"), cc.k_local);
    w.put_usize(&format!("{prefix}.k_map"), cc.k_map);
    w.put_usize(&format!("{prefix}.k_reduce"), cc.k_reduce);
    w.put_f64(&format!("{prefix}.hdfs_block_bytes"), cc.hdfs_block_bytes);
    w.put_usize(&format!("{prefix}.nodes"), cc.nodes);
    w.put_usize(&format!("{prefix}.vcores_per_node"), cc.vcores_per_node);
    w.put_f64(&format!("{prefix}.yarn_mem_per_node"), cc.yarn_mem_per_node);
    w.put_f64(&format!("{prefix}.clock_hz"), cc.clock_hz);
    w.put_usize(&format!("{prefix}.spark_executors"), cc.spark_executors);
    w.put_usize(&format!("{prefix}.spark_executor_cores"), cc.spark_executor_cores);
    w.put_f64(&format!("{prefix}.spark_executor_mem_bytes"), cc.spark_executor_mem_bytes);
}

pub(crate) fn get_cluster(s: &Section<'_>, prefix: &str) -> Result<ClusterConfig, String> {
    Ok(ClusterConfig {
        cp_heap_bytes: s.f64(&format!("{prefix}.cp_heap_bytes"))?,
        map_heap_bytes: s.f64(&format!("{prefix}.map_heap_bytes"))?,
        reduce_heap_bytes: s.f64(&format!("{prefix}.reduce_heap_bytes"))?,
        k_local: s.usize(&format!("{prefix}.k_local"))?,
        k_map: s.usize(&format!("{prefix}.k_map"))?,
        k_reduce: s.usize(&format!("{prefix}.k_reduce"))?,
        hdfs_block_bytes: s.f64(&format!("{prefix}.hdfs_block_bytes"))?,
        nodes: s.usize(&format!("{prefix}.nodes"))?,
        vcores_per_node: s.usize(&format!("{prefix}.vcores_per_node"))?,
        yarn_mem_per_node: s.f64(&format!("{prefix}.yarn_mem_per_node"))?,
        clock_hz: s.f64(&format!("{prefix}.clock_hz"))?,
        spark_executors: s.usize(&format!("{prefix}.spark_executors"))?,
        spark_executor_cores: s.usize(&format!("{prefix}.spark_executor_cores"))?,
        spark_executor_mem_bytes: s.f64(&format!("{prefix}.spark_executor_mem_bytes"))?,
    })
}

pub(crate) fn put_sysconf(w: &mut Writer, prefix: &str, cfg: &SystemConfig) {
    w.put_i64(&format!("{prefix}.blocksize"), cfg.blocksize);
    w.put_f64(&format!("{prefix}.mem_budget_ratio"), cfg.mem_budget_ratio);
    w.put_usize(&format!("{prefix}.num_reducers"), cfg.num_reducers);
    w.put_usize(&format!("{prefix}.replication"), cfg.replication);
    w.put_f64(&format!("{prefix}.sparse_threshold"), cfg.sparse_threshold);
    w.put_f64(&format!("{prefix}.unknown_iterations"), cfg.unknown_iterations);
    w.put_f64(&format!("{prefix}.partition_bytes"), cfg.partition_bytes);
}

pub(crate) fn get_sysconf(s: &Section<'_>, prefix: &str) -> Result<SystemConfig, String> {
    Ok(SystemConfig {
        blocksize: s.i64(&format!("{prefix}.blocksize"))?,
        mem_budget_ratio: s.f64(&format!("{prefix}.mem_budget_ratio"))?,
        num_reducers: s.usize(&format!("{prefix}.num_reducers"))?,
        replication: s.usize(&format!("{prefix}.replication"))?,
        sparse_threshold: s.f64(&format!("{prefix}.sparse_threshold"))?,
        unknown_iterations: s.f64(&format!("{prefix}.unknown_iterations"))?,
        partition_bytes: s.f64(&format!("{prefix}.partition_bytes"))?,
    })
}

pub(crate) fn put_constants(w: &mut Writer, prefix: &str, k: &CostConstants) {
    w.put_f64(&format!("{prefix}.hdfs_read_binaryblock"), k.hdfs_read_binaryblock);
    w.put_f64(&format!("{prefix}.hdfs_read_text"), k.hdfs_read_text);
    w.put_f64(&format!("{prefix}.hdfs_write_binaryblock"), k.hdfs_write_binaryblock);
    w.put_f64(&format!("{prefix}.hdfs_write_text"), k.hdfs_write_text);
    w.put_f64(&format!("{prefix}.local_read"), k.local_read);
    w.put_f64(&format!("{prefix}.local_write"), k.local_write);
    w.put_f64(&format!("{prefix}.dcache_read"), k.dcache_read);
    w.put_f64(&format!("{prefix}.shuffle_bw"), k.shuffle_bw);
    w.put_f64(&format!("{prefix}.mem_bw"), k.mem_bw);
    w.put_f64(&format!("{prefix}.job_latency"), k.job_latency);
    w.put_f64(&format!("{prefix}.task_latency"), k.task_latency);
    w.put_f64(&format!("{prefix}.bookkeeping"), k.bookkeeping);
    w.put_f64(&format!("{prefix}.dop_scale"), k.dop_scale);
    w.put_f64(&format!("{prefix}.spark_job_latency"), k.spark_job_latency);
    w.put_f64(&format!("{prefix}.spark_stage_latency"), k.spark_stage_latency);
    w.put_f64(&format!("{prefix}.spark_task_latency"), k.spark_task_latency);
    w.put_f64(&format!("{prefix}.spark_shuffle_write"), k.spark_shuffle_write);
    w.put_f64(&format!("{prefix}.spark_shuffle_read"), k.spark_shuffle_read);
    w.put_f64(&format!("{prefix}.spark_broadcast_bw"), k.spark_broadcast_bw);
    w.put_f64(&format!("{prefix}.flop_efficiency"), k.flop_efficiency);
}

pub(crate) fn get_constants(s: &Section<'_>, prefix: &str) -> Result<CostConstants, String> {
    Ok(CostConstants {
        hdfs_read_binaryblock: s.f64(&format!("{prefix}.hdfs_read_binaryblock"))?,
        hdfs_read_text: s.f64(&format!("{prefix}.hdfs_read_text"))?,
        hdfs_write_binaryblock: s.f64(&format!("{prefix}.hdfs_write_binaryblock"))?,
        hdfs_write_text: s.f64(&format!("{prefix}.hdfs_write_text"))?,
        local_read: s.f64(&format!("{prefix}.local_read"))?,
        local_write: s.f64(&format!("{prefix}.local_write"))?,
        dcache_read: s.f64(&format!("{prefix}.dcache_read"))?,
        shuffle_bw: s.f64(&format!("{prefix}.shuffle_bw"))?,
        mem_bw: s.f64(&format!("{prefix}.mem_bw"))?,
        job_latency: s.f64(&format!("{prefix}.job_latency"))?,
        task_latency: s.f64(&format!("{prefix}.task_latency"))?,
        bookkeeping: s.f64(&format!("{prefix}.bookkeeping"))?,
        dop_scale: s.f64(&format!("{prefix}.dop_scale"))?,
        spark_job_latency: s.f64(&format!("{prefix}.spark_job_latency"))?,
        spark_stage_latency: s.f64(&format!("{prefix}.spark_stage_latency"))?,
        spark_task_latency: s.f64(&format!("{prefix}.spark_task_latency"))?,
        spark_shuffle_write: s.f64(&format!("{prefix}.spark_shuffle_write"))?,
        spark_shuffle_read: s.f64(&format!("{prefix}.spark_shuffle_read"))?,
        spark_broadcast_bw: s.f64(&format!("{prefix}.spark_broadcast_bw"))?,
        flop_efficiency: s.f64(&format!("{prefix}.flop_efficiency"))?,
    })
}

pub(crate) fn put_fault(w: &mut Writer, prefix: &str, fp: &FaultProfile) {
    w.put_f64(&format!("{prefix}.mr_fail_p"), fp.mr_fail_p);
    w.put_f64(&format!("{prefix}.spark_fail_p"), fp.spark_fail_p);
    w.put_f64(&format!("{prefix}.straggler_frac"), fp.straggler_frac);
    w.put_f64(&format!("{prefix}.straggler_slowdown"), fp.straggler_slowdown);
    w.put_usize(&format!("{prefix}.max_attempts"), fp.max_attempts);
    w.put_f64(&format!("{prefix}.backoff_base"), fp.backoff_base);
    w.put_bool(&format!("{prefix}.speculative"), fp.speculative);
}

pub(crate) fn get_fault(s: &Section<'_>, prefix: &str) -> Result<FaultProfile, String> {
    Ok(FaultProfile {
        mr_fail_p: s.f64(&format!("{prefix}.mr_fail_p"))?,
        spark_fail_p: s.f64(&format!("{prefix}.spark_fail_p"))?,
        straggler_frac: s.f64(&format!("{prefix}.straggler_frac"))?,
        straggler_slowdown: s.f64(&format!("{prefix}.straggler_slowdown"))?,
        max_attempts: s.usize(&format!("{prefix}.max_attempts"))?,
        backoff_base: s.f64(&format!("{prefix}.backoff_base"))?,
        speculative: s.bool(&format!("{prefix}.speculative"))?,
    })
}

pub(crate) fn put_corrections(w: &mut Writer, prefix: &str, c: &Corrections) {
    w.put_f64(&format!("{prefix}.compute"), c.compute);
    w.put_f64(&format!("{prefix}.read"), c.read);
    w.put_f64(&format!("{prefix}.write"), c.write);
    w.put_f64(&format!("{prefix}.latency"), c.latency);
    w.put_f64(&format!("{prefix}.distributed"), c.distributed);
}

pub(crate) fn get_corrections(s: &Section<'_>, prefix: &str) -> Result<Corrections, String> {
    Ok(Corrections {
        compute: s.f64(&format!("{prefix}.compute"))?,
        read: s.f64(&format!("{prefix}.read"))?,
        write: s.f64(&format!("{prefix}.write"))?,
        latency: s.f64(&format!("{prefix}.latency"))?,
        distributed: s.f64(&format!("{prefix}.distributed"))?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_serializers_round_trip_bitwise() {
        let cc = ClusterConfig::paper_cluster();
        let cfg = SystemConfig::default();
        let k = CostConstants::default();
        let fp = FaultProfile::chaos();
        let mut w = Writer::new("plan");
        w.section("s");
        put_cluster(&mut w, "cc", &cc);
        put_sysconf(&mut w, "cfg", &cfg);
        put_constants(&mut w, "k", &k);
        put_fault(&mut w, "fp", &fp);
        let text = w.finish();
        let r = Reader::parse(&text).unwrap();
        let s = r.section("s").unwrap();
        assert_eq!(get_cluster(&s, "cc").unwrap(), cc);
        assert_eq!(get_sysconf(&s, "cfg").unwrap(), cfg);
        assert_eq!(get_constants(&s, "k").unwrap(), k);
        assert_eq!(get_fault(&s, "fp").unwrap(), fp);
    }

    #[test]
    fn unknown_kind_is_a_diagnostic() {
        let w = Writer::new("mystery");
        let text = w.finish();
        let err = Artifact::decode(&text).unwrap_err();
        assert!(err.contains("unknown kind"), "{err}");
    }

    #[test]
    fn save_load_round_trips_via_fs() {
        let dir = std::env::temp_dir().join(format!("sysds_artifact_test_{}", std::process::id()));
        let path = dir.join("cache.sysdsart");
        let snap = CacheSnapshot::empty(1024);
        save(&path, &Artifact::CacheSnapshot(snap)).unwrap();
        match load(&path).unwrap() {
            Artifact::CacheSnapshot(s) => assert_eq!(s.capacity(), 1024),
            other => panic!("wrong kind: {other:?}"),
        }
        let err = load(&dir.join("missing.sysdsart")).unwrap_err();
        assert!(err.contains("cannot read"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
