//! Calibration profiles: the fitted [`Corrections`] and calibrated
//! [`CostConstants`] from a [`crate::feedback`] run, stamped with the
//! provenance needed to audit a loaded profile — seed, measurement mode,
//! case/record counts and before/after Q-error.
//!
//! A profile is the artifact the `--profile <path>` flag consumes: any
//! optimizer (`sweep`, `resource`, `gdf`) can run under constants
//! calibrated by an earlier `repro calibrate --save-profile` without
//! re-measuring anything.

use crate::conf::CostConstants;
use crate::feedback::{CalibrateOptions, CalibrationReport, Corrections, MeasureMode, QErrorSummary};

use super::codec::{Reader, Section, Writer};

/// Header kind token for calibration profiles.
pub const KIND: &str = "profile";

/// A persisted calibration outcome (see the module docs).
#[derive(Clone, Debug)]
pub struct CalibrationProfile {
    /// RNG seed the calibration ran with.
    pub seed: u64,
    /// Measurement mode: `"execute"` or `"simulated(noise=…)"`.
    pub mode: String,
    /// Whether the quick (CI-sized) workload set was used.
    pub quick: bool,
    /// Number of calibration cases measured.
    pub cases: usize,
    /// Number of per-block records the fit saw.
    pub records: usize,
    /// The fitted per-group multiplicative corrections.
    pub corrections: Corrections,
    /// The constants calibration started from.
    pub initial: CostConstants,
    /// The corrected constants (`corrections.apply(&initial)`).
    pub calibrated: CostConstants,
    /// Q-error under the initial constants.
    pub before: QErrorSummary,
    /// Q-error under the calibrated constants.
    pub after: QErrorSummary,
}

impl CalibrationProfile {
    /// Capture a profile from a finished calibration run.
    pub fn from_report(report: &CalibrationReport, opts: &CalibrateOptions) -> Self {
        let mode = match opts.mode {
            MeasureMode::Execute => "execute".to_string(),
            MeasureMode::Simulated { noise } => format!("simulated(noise={noise})"),
        };
        CalibrationProfile {
            seed: opts.seed,
            mode,
            quick: opts.quick,
            cases: report.cases,
            records: report.records.len(),
            corrections: report.corrections.clone(),
            initial: report.initial.clone(),
            calibrated: report.calibrated.clone(),
            before: report.before,
            after: report.after,
        }
    }

    /// The constants an optimizer should run under when this profile is
    /// loaded.
    pub fn constants(&self) -> &CostConstants {
        &self.calibrated
    }

    /// One-line provenance summary (printed when a profile is loaded, so
    /// the run is auditable).
    pub fn summary(&self) -> String {
        format!(
            "profile: seed={} mode={} quick={} cases={} records={} qerror geo-mean {:.3} -> {:.3}",
            self.seed,
            self.mode,
            self.quick,
            self.cases,
            self.records,
            self.before.geo_mean,
            self.after.geo_mean
        )
    }

    /// Serialize to the artifact text form.
    pub fn encode(&self) -> String {
        let mut w = Writer::new(KIND);
        w.section("provenance");
        w.put_u64("seed", self.seed);
        w.put_str("mode", &self.mode);
        w.put_bool("quick", self.quick);
        w.put_usize("cases", self.cases);
        w.put_usize("records", self.records);
        put_qerror(&mut w, "before", &self.before);
        put_qerror(&mut w, "after", &self.after);
        w.section("constants");
        super::put_corrections(&mut w, "corrections", &self.corrections);
        super::put_constants(&mut w, "initial", &self.initial);
        super::put_constants(&mut w, "calibrated", &self.calibrated);
        w.finish()
    }

    /// Parse from the artifact text form.
    pub fn decode(text: &str) -> Result<Self, String> {
        let reader = Reader::parse(text)?;
        if reader.kind() != KIND {
            return Err(format!("artifact: expected a '{KIND}' artifact, got '{}'", reader.kind()));
        }
        Self::decode_from(&reader)
    }

    pub(crate) fn decode_from(reader: &Reader) -> Result<Self, String> {
        let p = reader.section("provenance")?;
        let c = reader.section("constants")?;
        let profile = CalibrationProfile {
            seed: p.u64("seed")?,
            mode: p.str("mode")?,
            quick: p.bool("quick")?,
            cases: p.usize("cases")?,
            records: p.usize("records")?,
            before: get_qerror(&p, "before")?,
            after: get_qerror(&p, "after")?,
            corrections: super::get_corrections(&c, "corrections")?,
            initial: super::get_constants(&c, "initial")?,
            calibrated: super::get_constants(&c, "calibrated")?,
        };
        // a profile whose calibrated constants cannot be priced (zero or
        // non-finite bandwidths) must fail at load time, not poison a run
        profile
            .calibrated
            .validate()
            .map_err(|e| format!("artifact: profile carries unusable constants: {e}"))?;
        Ok(profile)
    }
}

fn put_qerror(w: &mut Writer, prefix: &str, q: &QErrorSummary) {
    w.put_usize(&format!("{prefix}.n"), q.n);
    w.put_f64(&format!("{prefix}.geo_mean"), q.geo_mean);
    w.put_f64(&format!("{prefix}.p95"), q.p95);
    w.put_f64(&format!("{prefix}.within_2x"), q.within_2x);
}

fn get_qerror(s: &Section<'_>, prefix: &str) -> Result<QErrorSummary, String> {
    Ok(QErrorSummary {
        n: s.usize(&format!("{prefix}.n"))?,
        geo_mean: s.f64(&format!("{prefix}.geo_mean"))?,
        p95: s.f64(&format!("{prefix}.p95"))?,
        within_2x: s.f64(&format!("{prefix}.within_2x"))?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CalibrationProfile {
        let corrections = Corrections {
            compute: 1.5,
            read: 0.8,
            write: 1.0,
            latency: 2.0,
            distributed: 1.1,
        };
        let initial = CostConstants::default();
        let calibrated = corrections.apply(&initial);
        CalibrationProfile {
            seed: 42,
            mode: "simulated(noise=0.05)".to_string(),
            quick: true,
            cases: 6,
            records: 120,
            corrections,
            initial,
            calibrated,
            before: QErrorSummary { n: 120, geo_mean: 1.9, p95: 3.4, within_2x: 0.55 },
            after: QErrorSummary { n: 120, geo_mean: 1.1, p95: 1.6, within_2x: 0.97 },
        }
    }

    #[test]
    fn profile_round_trips_bitwise() {
        let p = sample();
        let text = p.encode();
        let back = CalibrationProfile::decode(&text).unwrap();
        assert_eq!(back.seed, p.seed);
        assert_eq!(back.mode, p.mode);
        assert_eq!(back.quick, p.quick);
        assert_eq!(back.cases, p.cases);
        assert_eq!(back.records, p.records);
        assert_eq!(back.calibrated, p.calibrated);
        assert_eq!(back.initial, p.initial);
        assert_eq!(back.corrections.compute.to_bits(), p.corrections.compute.to_bits());
        assert_eq!(back.before.geo_mean.to_bits(), p.before.geo_mean.to_bits());
        assert_eq!(back.after.within_2x.to_bits(), p.after.within_2x.to_bits());
        assert_eq!(back.encode(), text);
    }

    #[test]
    fn unusable_constants_rejected_at_load() {
        let mut p = sample();
        p.calibrated.mem_bw = 0.0;
        let text = p.encode();
        let err = CalibrationProfile::decode(&text).unwrap_err();
        assert!(err.contains("unusable constants"), "{err}");
    }

    #[test]
    fn summary_names_the_provenance() {
        let s = sample().summary();
        assert!(s.contains("seed=42"), "{s}");
        assert!(s.contains("simulated"), "{s}");
    }
}
