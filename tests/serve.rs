//! Integration battery for the `repro serve` daemon (`src/serve/`):
//!
//! * **Golden protocol transcript** — a fixed request script covering
//!   every request kind plus the malformed/unknown-key/over-budget error
//!   paths, compared byte-for-byte against a checked-in snapshot
//!   (bless-on-first-run, like `tests/golden.rs`) and asserted
//!   byte-stable across `--threads` settings.
//! * **Concurrency stress** — N OS threads hammering one shared
//!   [`ServeState`] must each receive responses bitwise identical to a
//!   serial run on a fresh state.
//! * **Interleaving property** — shuffled request orders never change
//!   any request's outcome, ladder level, or downgrade reason codes.
//! * **Warm-start regressions** — a `--warm-cache` boot answers its
//!   first request with cache hits > 0 and the cold argmin bitwise; a
//!   `--profile` boot runs under calibrated constants.
//! * **One-shot budget regressions** — `Evaluator::set_budget` makes
//!   `gdf`/`resource` runs fail softly with the stable
//!   `budget-exceeded:<reason>` error, and a generous or absent budget
//!   leaves results bitwise unchanged.

use std::path::PathBuf;
use std::sync::Arc;

use systemds::api::{
    budget_error_reason, calibrate, linreg_cg_args, save_artifact, Artifact, Budget,
    CacheSnapshot, CalibrateOptions, CalibrationProfile, DataScenario, Evaluator, GdfSpec,
    MeasureMode, ResourceGrid, Scenario, BUDGET_ERROR_PREFIX, BUDGET_REASON_CANDIDATES,
    BUDGET_REASON_DEADLINE, LINREG_CG,
};
use systemds::api::FaultProfile;
use systemds::opt::{gdf, resource};
use systemds::serve::{serve_lines, serve_tcp_until, ServeOptions, ServeState};
use systemds::util::prop::forall;

// ---------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------

fn state(threads: usize) -> ServeState {
    ServeState::new(&ServeOptions { threads, ..Default::default() })
        .expect("default serve state boots")
}

/// Per-test scratch file under a pid-unique directory, so concurrent
/// test binaries never race on the same artifact paths.
fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sysds_serve_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create serve test dir");
    dir.join(name)
}

/// Extract `key=` from a rendered response line.
fn field<'a>(resp: &'a str, key: &str) -> Option<&'a str> {
    resp.split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .find(|(k, _)| *k == key)
        .map(|(_, v)| v)
}

// ---------------------------------------------------------------------
// Golden protocol transcript
// ---------------------------------------------------------------------

/// The fixed request script. Budgeted lines only use bounds whose
/// outcome is deterministic: `budget_candidates=1` (clock-free; every
/// multi-candidate batch trips) and `budget_ms=0` (the deadline is
/// already in the past when the first check runs).
const TRANSCRIPT: &[&str] = &[
    "# serve golden transcript — regenerate: rm tests/golden/serve_transcript.txt",
    "cmd=stats id=s0",
    "cmd=optimize id=o1 scenario=xs",
    "cmd=optimize id=o2 scenario=xs",
    "cmd=optimize id=o3 scenario=xl1 script=cg iters=5",
    "cmd=sweep id=w1 scenario=xs heaps=512,2048",
    "cmd=gdf id=g1 scenario=xs script=cg iters=2",
    "cmd=verify id=v1 scenario=xs",
    "cmd=verify id=v2 scenario=xs backend=spark script=cg iters=2",
    "what is this",
    "cmd=optimize",
    "cmd=bogus id=e1 scenario=xs",
    "cmd=optimize id=e2 scenario=atlantis",
    "cmd=optimize id=e3 scenario=xs iters=zero",
    "cmd=optimize id=e4 scenario=xs flavor=red",
    "cmd=optimize id=e5 scenario=xs scenario=xs",
    "cmd=gdf id=b1 scenario=xs script=cg iters=2 budget_candidates=1",
    "cmd=gdf id=b2 scenario=xs script=cg iters=2 budget_ms=0",
    "cmd=sweep id=b3 scenario=xs budget_ms=0",
    "cmd=stats id=s1",
];

/// Stats-only fields whose values are inherently volatile (wall-clock
/// latencies, shared-cache race outcomes, host thread count). Every
/// other response byte must be stable across runs and `--threads`.
const VOLATILE_KEYS: &[&str] = &[
    "cache_hits",
    "cache_misses",
    "cache_hit_rate",
    "cache_entries",
    "p50_us",
    "p99_us",
    "threads",
];

fn normalize(line: &str) -> String {
    line.split_whitespace()
        .map(|tok| match tok.split_once('=') {
            Some((k, _)) if VOLATILE_KEYS.contains(&k) => format!("{k}=_"),
            _ => tok.to_string(),
        })
        .collect::<Vec<_>>()
        .join(" ")
}

/// Run the transcript through the stdin/stdout transport
/// ([`serve_lines`]) on a fresh state and return normalized response
/// lines.
fn run_transcript(threads: usize) -> Vec<String> {
    run_transcript_on(&state(threads))
}

fn run_transcript_on(state: &ServeState) -> Vec<String> {
    let input = TRANSCRIPT.join("\n");
    let mut out: Vec<u8> = Vec::new();
    serve_lines(state, std::io::Cursor::new(input), &mut out).expect("in-memory serve session");
    String::from_utf8(out)
        .expect("responses are utf-8")
        .lines()
        .map(normalize)
        .collect()
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../tests/golden/serve_transcript.txt")
}

/// One response line per non-comment request line, byte-stable across
/// thread counts, matching the checked-in snapshot (blessed on first
/// run).
#[test]
fn golden_transcript_is_byte_stable_across_threads() {
    let t1 = run_transcript(1);
    let comments =
        TRANSCRIPT.iter().filter(|l| l.trim().is_empty() || l.trim().starts_with('#')).count();
    assert_eq!(
        t1.len(),
        TRANSCRIPT.len() - comments,
        "exactly one response per non-comment request line"
    );
    let t4 = run_transcript(4);
    assert_eq!(t1, t4, "responses must be byte-stable across --threads");

    let rendered = t1.join("\n") + "\n";
    let path = golden_path();
    if !path.exists() {
        std::fs::create_dir_all(path.parent().expect("golden dir")).expect("create golden dir");
        std::fs::write(&path, &rendered).expect("write golden transcript");
        eprintln!("blessed new golden snapshot: {}", path.display());
        return;
    }
    let expected = std::fs::read_to_string(&path).expect("read golden transcript");
    assert_eq!(
        rendered,
        expected,
        "serve transcript diverged from {} — delete the snapshot and re-run to re-bless",
        path.display()
    );
}

/// Structural pins that hold regardless of snapshot state: error codes,
/// ladder levels and downgrade trails land where the protocol promises.
#[test]
fn transcript_structure_pins() {
    let resp = run_transcript(1);
    let by_id = |id: &str| -> &String {
        resp.iter()
            .find(|l| field(l, "id") == Some(id))
            .unwrap_or_else(|| panic!("response for id={id}"))
    };

    // Repeated identical request: identical bitwise answer.
    let o1 = by_id("o1");
    let o2 = by_id("o2");
    assert_eq!(field(o1, "cost_bits"), field(o2, "cost_bits"));
    assert_eq!(field(o1, "backend"), field(o2, "backend"));
    for id in ["o1", "o2", "o3", "w1", "g1"] {
        let l = by_id(id);
        assert_eq!(field(l, "ok"), Some("true"), "{l}");
        assert_eq!(field(l, "level"), Some("full"), "{l}");
        assert_eq!(field(l, "downgrade"), Some("none"), "{l}");
    }
    for (id, code) in [
        ("e1", "unknown-cmd"),
        ("e2", "unknown-scenario"),
        ("e3", "bad-value"),
        ("e4", "unknown-key"),
        ("e5", "duplicate-key"),
    ] {
        let l = by_id(id);
        assert_eq!(field(l, "ok"), Some("false"), "{l}");
        assert_eq!(field(l, "code"), Some(code), "{l}");
    }
    // Over-budget optimizer requests fail soft: terminal cached rung,
    // machine-readable reason trail, still a full answer.
    for (id, reason) in [
        ("b1", "candidates,candidates"),
        ("b2", "deadline,deadline"),
        ("b3", "deadline,deadline"),
    ] {
        let l = by_id(id);
        assert_eq!(field(l, "ok"), Some("true"), "{l}");
        assert_eq!(field(l, "level"), Some("cached"), "{l}");
        assert_eq!(field(l, "downgrade"), Some(reason), "{l}");
        assert!(field(l, "cost_bits").is_some(), "{l}");
    }
    // b3's scenario/script was decided by o1 at full fidelity, so the
    // cached rung answers from the argmin table; b1/b2's key was never
    // decided, so they fall back to the un-budgeted default plan.
    assert_eq!(field(by_id("b3"), "source"), Some("argmin-table"));
    assert_eq!(field(by_id("b3"), "cost_bits"), field(by_id("o1"), "cost_bits"));
    assert_eq!(field(by_id("b1"), "source"), Some("default-plan"));
    assert_eq!(field(by_id("b2"), "source"), Some("default-plan"));
    assert_eq!(field(by_id("b1"), "cost_bits"), field(by_id("b2"), "cost_bits"));

    // The trailing stats response saw every earlier request.
    let s1 = by_id("s1");
    let n = (TRANSCRIPT.len() - 1) as u64; // minus the comment line
    assert_eq!(field(s1, "requests"), Some(format!("{}", n - 1).as_str()), "{s1}");
    // "what is this", the cmd-less line, and e1..e5.
    assert_eq!(field(s1, "errors"), Some("7"), "{s1}");
    assert_eq!(field(s1, "downgraded"), Some("3"), "{s1}");
    assert_eq!(field(s1, "downgrade_deadline"), Some("4"), "{s1}");
    assert_eq!(field(s1, "downgrade_candidates"), Some("2"), "{s1}");
}

// ---------------------------------------------------------------------
// Concurrency stress
// ---------------------------------------------------------------------

/// Request mix used by the stress and interleaving tests: no `stats`
/// lines (their counters are intentionally volatile), everything else
/// deterministic by design.
fn stress_requests() -> Vec<String> {
    vec![
        "cmd=optimize id=q1 scenario=xs".to_string(),
        "cmd=optimize id=q2 scenario=xl1".to_string(),
        "cmd=optimize id=q3 scenario=xl1 script=cg iters=3".to_string(),
        "cmd=verify id=q4 scenario=xs backend=spark".to_string(),
        "cmd=gdf id=q5 scenario=xs script=cg iters=2".to_string(),
    ]
}

/// N concurrent clients of one shared state each see responses bitwise
/// identical to a serial client on a fresh state — the shared memo and
/// cache are invisible in response bytes.
#[test]
fn concurrent_clients_match_serial_bitwise() {
    let reqs = stress_requests();
    let serial = state(1);
    let baseline: Vec<String> =
        reqs.iter().map(|r| serial.handle_line(r).expect("response")).collect();

    let shared = Arc::new(state(2));
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let shared = Arc::clone(&shared);
            let reqs = reqs.clone();
            std::thread::spawn(move || {
                reqs.iter()
                    .map(|r| shared.handle_line(r).expect("response"))
                    .collect::<Vec<String>>()
            })
        })
        .collect();
    for h in handles {
        let got = h.join().expect("client thread");
        assert_eq!(got, baseline, "concurrent responses must match the serial run bitwise");
    }

    let stats = shared.stats_snapshot();
    assert_eq!(stats.requests, (reqs.len() * 4) as u64);
    assert_eq!(stats.errors, 0);
}

// ---------------------------------------------------------------------
// Interleaving property
// ---------------------------------------------------------------------

/// Shuffling the request order never changes any request's outcome
/// tuple (ok, level/code, downgrade trail, cost bits). Budgeted
/// requests use scenario × script × iters keys no full-fidelity request
/// writes, so even the terminal cached rung is order-independent.
#[test]
fn interleaving_order_never_changes_outcomes() {
    let reqs: Vec<String> = vec![
        "cmd=optimize id=f1 scenario=xs".to_string(),
        "cmd=optimize id=f2 scenario=xl1".to_string(),
        "cmd=gdf id=b1 scenario=xl2 script=cg iters=3 budget_candidates=1".to_string(),
        "cmd=sweep id=b2 scenario=xl3 budget_ms=0".to_string(),
        "cmd=flying id=e1 scenario=xs".to_string(),
        "cmd=optimize id=e2 scenario=xs budget_candidates=zero".to_string(),
    ];
    let outcome = |line: &str| -> (String, String, String, String) {
        (
            field(line, "ok").unwrap_or("").to_string(),
            field(line, "level").or_else(|| field(line, "code")).unwrap_or("").to_string(),
            field(line, "downgrade").unwrap_or("").to_string(),
            field(line, "cost_bits").unwrap_or("").to_string(),
        )
    };
    let run = |order: &[usize]| -> Vec<(String, (String, String, String, String))> {
        let st = state(1);
        let mut got: Vec<_> = order
            .iter()
            .map(|&i| {
                let resp = st.handle_line(&reqs[i]).expect("response");
                (field(&resp, "id").expect("id echoed").to_string(), outcome(&resp))
            })
            .collect();
        got.sort();
        got
    };

    let baseline = run(&(0..reqs.len()).collect::<Vec<_>>());
    forall(
        6,
        0xC0FFEE,
        |rng| {
            // Fisher–Yates over the request indices.
            let mut order: Vec<usize> = (0..reqs.len()).collect();
            for i in (1..order.len()).rev() {
                order.swap(i, rng.below(i as u64 + 1) as usize);
            }
            order
        },
        |order| {
            let got = run(order);
            if got == baseline {
                Ok(())
            } else {
                Err(format!("outcomes changed under reordering:\n{got:?}\nvs\n{baseline:?}"))
            }
        },
    );
}

// ---------------------------------------------------------------------
// Warm-start regressions
// ---------------------------------------------------------------------

/// A daemon booted from a cost-cache snapshot answers its first request
/// with cache hits > 0 and reproduces the cold argmin bitwise.
#[test]
fn warm_cache_boot_replays_cold_argmin_with_hits() {
    let cold = state(1);
    let req = "cmd=optimize id=c scenario=xl1";
    let cold_resp = cold.handle_line(req).expect("cold response");
    assert_eq!(field(&cold_resp, "ok"), Some("true"));
    let cache = cold.cache().expect("cost cache is on by default");
    let snap = CacheSnapshot::from_cache(&cache);
    assert!(!snap.is_empty(), "cold run must populate the shared cache");

    let path = tmp("warm_boot.costcache");
    save_artifact(&path, &Artifact::CacheSnapshot(snap)).expect("save snapshot");

    let warm = ServeState::new(&ServeOptions {
        threads: 1,
        warm_cache: Some(path),
        ..Default::default()
    })
    .expect("warm serve state boots");
    assert!(
        warm.boot_summary().contains("warm="),
        "boot banner must report the warmed entries: {}",
        warm.boot_summary()
    );
    let before = warm.cache_stats();
    let warm_resp = warm.handle_line(req).expect("warm response");
    let after = warm.cache_stats();
    assert!(
        after.hits > before.hits,
        "first warm request must be served with cache hits (before {} / after {})",
        before.hits,
        after.hits
    );
    assert_eq!(field(&warm_resp, "cost_bits"), field(&cold_resp, "cost_bits"));
    assert_eq!(field(&warm_resp, "backend"), field(&cold_resp, "backend"));
}

/// `--warm-cache` under `--no-cost-cache` is a boot-time contradiction,
/// and a wrong-kind artifact is a diagnostic, not a panic.
#[test]
fn warm_cache_boot_diagnostics() {
    let err = ServeState::new(&ServeOptions {
        no_cost_cache: true,
        warm_cache: Some(tmp("unused.costcache")),
        ..Default::default()
    })
    .expect_err("contradictory boot must fail");
    assert!(err.contains("--no-cost-cache"), "{err}");

    let err = ServeState::new(&ServeOptions {
        warm_cache: Some(tmp("missing.costcache")),
        ..Default::default()
    })
    .expect_err("missing snapshot must fail");
    assert!(!err.is_empty());
}

/// A daemon booted under a calibration profile runs every request with
/// the calibrated constants — deterministically so.
#[test]
fn profile_boot_serves_under_calibrated_constants() {
    let opts = CalibrateOptions {
        seed: 7,
        quick: true,
        threads: 1,
        mode: MeasureMode::Simulated { noise: 0.0 },
        ..Default::default()
    };
    let report = calibrate(&opts).expect("simulated calibration");
    let profile = CalibrationProfile::from_report(&report, &opts);
    let path = tmp("boot.profile");
    save_artifact(&path, &Artifact::Profile(profile)).expect("save profile");

    let boot = || {
        ServeState::new(&ServeOptions {
            threads: 1,
            profile: Some(path.clone()),
            ..Default::default()
        })
        .expect("profile serve state boots")
    };
    let a = boot();
    assert!(a.boot_summary().contains("calibrated"), "{}", a.boot_summary());
    let ra = a.handle_line("cmd=optimize id=p scenario=xs").expect("response");
    assert_eq!(field(&ra, "ok"), Some("true"));
    let rb = boot().handle_line("cmd=optimize id=p scenario=xs").expect("response");
    assert_eq!(ra, rb, "calibrated answers must be deterministic across boots");
}

// ---------------------------------------------------------------------
// One-shot budget regressions (the `--budget-ms` / `--budget-candidates`
// CLI path: Evaluator::set_budget + the cooperative checks in
// opt/evaluate.rs)
// ---------------------------------------------------------------------

fn xs_cg_gdf_spec() -> GdfSpec {
    let mut spec = GdfSpec::linreg_cg(DataScenario::from(&Scenario::xs()), 2);
    spec.threads = 1;
    spec
}

/// A candidate budget of 1 trips the gdf run with the stable
/// machine-readable error, every time.
#[test]
fn gdf_candidate_budget_fails_soft_and_deterministically() {
    let mut reasons = Vec::new();
    for _ in 0..3 {
        let mut eval = Evaluator::new(1);
        eval.set_budget(Some(Budget::new(None, Some(1))));
        let err = gdf::optimize_with(&xs_cg_gdf_spec(), &mut eval)
            .expect_err("budget of 1 candidate cannot cover a gdf enumeration");
        assert!(err.starts_with(BUDGET_ERROR_PREFIX), "{err}");
        reasons.push(budget_error_reason(&err).expect("budget reason"));
    }
    assert_eq!(reasons, vec![BUDGET_REASON_CANDIDATES; 3], "same reason code every run");
}

/// An already-expired wall-clock budget trips the resource grid before
/// any candidate is compiled.
#[test]
fn resource_deadline_budget_fails_soft() {
    let grid = ResourceGrid::new(
        LINREG_CG,
        linreg_cg_args(2),
        DataScenario::from(&Scenario::xs()),
    );
    let mut eval = Evaluator::new(1);
    eval.set_budget(Some(Budget::new(Some(0), None)));
    let err = resource::optimize_grid_with(&grid, &mut eval)
        .expect_err("expired deadline must trip the run");
    assert_eq!(budget_error_reason(&err), Some(BUDGET_REASON_DEADLINE), "{err}");
    assert_eq!(eval.distinct_plans(), 0, "no plan may be compiled after expiry");
}

// ---------------------------------------------------------------------
// Chaos + crash safety (`--fault-profile`, `--spill-argmin`,
// `--idle-timeout`)
// ---------------------------------------------------------------------

/// The full golden transcript served under the bundled chaos profile:
/// still one well-formed response per request, byte-stable across
/// thread counts, snapshotted separately (bless-on-first-run) because
/// fault-aware costs differ from the fault-free transcript.
#[test]
fn chaos_transcript_is_byte_stable_across_threads() {
    let chaos_state = |threads: usize| {
        ServeState::new(&ServeOptions {
            threads,
            fault: FaultProfile::chaos(),
            ..Default::default()
        })
        .expect("chaos serve state boots")
    };
    let s1 = chaos_state(1);
    assert!(s1.boot_summary().contains("fault=on"), "{}", s1.boot_summary());
    let t1 = run_transcript_on(&s1);
    let t4 = run_transcript_on(&chaos_state(4));
    assert_eq!(t1, t4, "chaos responses must be byte-stable across --threads");
    for line in &t1 {
        assert!(
            field(line, "ok").is_some(),
            "every chaos response must be well-formed: {line}"
        );
    }

    let rendered = t1.join("\n") + "\n";
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("../tests/golden/serve_transcript_chaos.txt");
    if !path.exists() {
        std::fs::create_dir_all(path.parent().expect("golden dir")).expect("create golden dir");
        std::fs::write(&path, &rendered).expect("write chaos transcript");
        eprintln!("blessed new golden snapshot: {}", path.display());
        return;
    }
    let expected = std::fs::read_to_string(&path).expect("read chaos transcript");
    assert_eq!(
        rendered,
        expected,
        "chaos serve transcript diverged from {} — delete the snapshot and re-run to re-bless",
        path.display()
    );
}

/// Ladder property under deadline jitter and fault profiles: budgeted
/// requests always descend the one-way ladder to `level=cached` with
/// the same machine-readable reason trail, bitwise-identically across
/// repeats, thread counts, and the fault dimension — the profile may
/// change costs, never outcomes or reason codes.
#[test]
fn prop_downgrade_ladder_is_stable_under_jitter_and_faults() {
    let requests = [
        ("cmd=sweep id=j1 scenario=xs budget_ms=0", "deadline,deadline"),
        ("cmd=gdf id=j2 scenario=xs script=cg iters=2 budget_candidates=1", "candidates,candidates"),
        ("cmd=gdf id=j3 scenario=xs script=cg iters=2 budget_ms=0", "deadline,deadline"),
    ];
    forall(
        8,
        0xDE1A7,
        |rng| {
            let chaos = rng.below(2) == 1;
            let threads = 1 + rng.below(3) as usize;
            let which = rng.below(requests.len() as u64) as usize;
            (chaos, threads, which)
        },
        |&(chaos, threads, which)| {
            let fault =
                if chaos { FaultProfile::chaos() } else { FaultProfile::none() };
            let st = ServeState::new(&ServeOptions { threads, fault, ..Default::default() })
                .map_err(|e| format!("boot: {e}"))?;
            let (req, trail) = requests[which];
            let first = st.handle_line(req).ok_or("no response")?;
            if field(&first, "ok") != Some("true") {
                return Err(format!("budgeted request must fail soft: {first}"));
            }
            if field(&first, "level") != Some("cached") {
                return Err(format!("ladder must land on the terminal rung: {first}"));
            }
            if field(&first, "downgrade") != Some(trail) {
                return Err(format!("reason trail must be {trail}: {first}"));
            }
            for _ in 0..2 {
                let again = st.handle_line(req).ok_or("no response")?;
                if again != first {
                    return Err(format!("replay drifted:\n{first}\nvs\n{again}"));
                }
            }
            Ok(())
        },
    );
}

/// Crash safety: a daemon with `--spill-argmin` persists its terminal
/// rung. A second boot on the same path reloads the table
/// (`argmin=persisted(n)` in the banner) and answers forced-downgrade
/// requests from it with `source=persisted`, bitwise-identical to the
/// pre-restart decision.
#[test]
fn spilled_argmin_survives_a_daemon_restart_bitwise() {
    let path = tmp("restart.argmin");
    let _ = std::fs::remove_file(&path);
    let boot = || {
        ServeState::new(&ServeOptions {
            threads: 1,
            spill_argmin: Some(path.clone()),
            ..Default::default()
        })
        .expect("spill serve state boots")
    };

    let a = boot();
    let decided = a.handle_line("cmd=optimize id=o scenario=xs").expect("response");
    assert_eq!(field(&decided, "ok"), Some("true"));
    assert!(path.exists(), "argmin table must spill after the decision");
    let own = a.handle_line("cmd=sweep id=c1 scenario=xs budget_ms=0").expect("response");
    assert_eq!(field(&own, "source"), Some("argmin-table"));
    drop(a);

    let b = boot();
    assert!(
        b.boot_summary().contains("argmin=persisted(1)"),
        "restarted banner must report the reloaded table: {}",
        b.boot_summary()
    );
    let replay = b.handle_line("cmd=sweep id=c2 scenario=xs budget_ms=0").expect("response");
    assert_eq!(field(&replay, "ok"), Some("true"));
    assert_eq!(field(&replay, "level"), Some("cached"));
    assert_eq!(field(&replay, "source"), Some("persisted"));
    assert_eq!(
        field(&replay, "cost_bits"),
        field(&decided, "cost_bits"),
        "restart must answer bitwise-identically from the persisted table"
    );
    assert_eq!(field(&replay, "backend"), field(&decided, "backend"));
}

/// Regenerate-don't-trust: a spilled table decided under a different
/// failure profile is priced wrong, not merely stale — the boot
/// discards it and the terminal rung re-decides.
#[test]
fn stale_spilled_argmin_is_discarded_at_boot() {
    let path = tmp("stale.argmin");
    let _ = std::fs::remove_file(&path);
    let a = ServeState::new(&ServeOptions {
        threads: 1,
        spill_argmin: Some(path.clone()),
        ..Default::default()
    })
    .expect("spill serve state boots");
    a.handle_line("cmd=optimize id=o scenario=xs").expect("response");
    drop(a);

    let b = ServeState::new(&ServeOptions {
        threads: 1,
        spill_argmin: Some(path),
        fault: FaultProfile::chaos(),
        ..Default::default()
    })
    .expect("chaos spill serve state boots");
    assert!(
        b.boot_summary().contains("argmin=persisted(0)"),
        "mismatched-context table must be discarded: {}",
        b.boot_summary()
    );
    let resp = b.handle_line("cmd=sweep id=c scenario=xs budget_ms=0").expect("response");
    assert_eq!(field(&resp, "source"), Some("default-plan"));
}

/// `--idle-timeout` on the TCP transport: a client that goes silent
/// past the deadline is closed cleanly (EOF on the client side, no
/// pinned handler thread), and the graceful drain still joins.
#[test]
fn idle_timeout_closes_silent_tcp_connections_cleanly() {
    use std::io::{BufRead, BufReader, ErrorKind, Write};
    use std::net::{TcpListener, TcpStream};
    use std::sync::atomic::{AtomicBool, Ordering};

    let state = Arc::new(
        ServeState::new(&ServeOptions {
            threads: 1,
            idle_timeout_ms: 200,
            ..Default::default()
        })
        .expect("serve state boots"),
    );
    assert_eq!(state.idle_timeout(), Some(std::time::Duration::from_millis(200)));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port");
    let addr = listener.local_addr().expect("local addr");
    let shutdown = Arc::new(AtomicBool::new(false));
    let server = {
        let state = Arc::clone(&state);
        let shutdown = Arc::clone(&shutdown);
        std::thread::spawn(move || serve_tcp_until(state, listener, shutdown))
    };

    let mut conn = TcpStream::connect(addr).expect("connect");
    conn.write_all(b"cmd=stats id=t1\n").expect("send request");
    let mut reader = BufReader::new(conn.try_clone().expect("clone socket"));
    let mut line = String::new();
    reader.read_line(&mut line).expect("read response");
    assert!(line.starts_with("id=t1 ok=true"), "{line}");

    // Go silent past the deadline: the daemon closes the socket, so the
    // next read sees EOF (or a reset, depending on the platform).
    line.clear();
    match reader.read_line(&mut line) {
        Ok(n) => assert_eq!(n, 0, "idle connection must be closed, got {line:?}"),
        Err(e) => assert!(
            matches!(e.kind(), ErrorKind::ConnectionReset | ErrorKind::ConnectionAborted),
            "unexpected error from closed socket: {e}"
        ),
    }

    shutdown.store(true, Ordering::SeqCst);
    server
        .join()
        .expect("accept loop joins")
        .expect("serve_tcp_until returns cleanly");
}

/// A generous budget is invisible: the gdf run produces bitwise the
/// same report as an unbudgeted one.
#[test]
fn generous_budget_leaves_results_bitwise_unchanged() {
    let spec = xs_cg_gdf_spec();
    let mut plain = Evaluator::new(1);
    let a = gdf::optimize_with(&spec, &mut plain).expect("unbudgeted gdf run");

    let mut budgeted = Evaluator::new(1);
    budgeted.set_budget(Some(Budget::new(Some(3_600_000), Some(1_000_000))));
    let b = gdf::optimize_with(&spec, &mut budgeted).expect("generously budgeted gdf run");

    assert_eq!(a.candidates.len(), b.candidates.len());
    assert_eq!(a.best().label(), b.best().label());
    assert_eq!(
        a.best().cost_secs.to_bits(),
        b.best().cost_secs.to_bits(),
        "budget plumbing must not perturb costs"
    );
}
