//! Integration battery for the `repro serve` daemon (`src/serve/`):
//!
//! * **Golden protocol transcript** — a fixed request script covering
//!   every request kind plus the malformed/unknown-key/over-budget error
//!   paths, compared byte-for-byte against a checked-in snapshot
//!   (bless-on-first-run, like `tests/golden.rs`) and asserted
//!   byte-stable across `--threads` settings.
//! * **Concurrency stress** — N OS threads hammering one shared
//!   [`ServeState`] must each receive responses bitwise identical to a
//!   serial run on a fresh state.
//! * **Interleaving property** — shuffled request orders never change
//!   any request's outcome, ladder level, or downgrade reason codes.
//! * **Warm-start regressions** — a `--warm-cache` boot answers its
//!   first request with cache hits > 0 and the cold argmin bitwise; a
//!   `--profile` boot runs under calibrated constants.
//! * **One-shot budget regressions** — `Evaluator::set_budget` makes
//!   `gdf`/`resource` runs fail softly with the stable
//!   `budget-exceeded:<reason>` error, and a generous or absent budget
//!   leaves results bitwise unchanged.

use std::path::PathBuf;
use std::sync::Arc;

use systemds::api::{
    budget_error_reason, calibrate, linreg_cg_args, save_artifact, Artifact, Budget,
    CacheSnapshot, CalibrateOptions, CalibrationProfile, DataScenario, Evaluator, GdfSpec,
    MeasureMode, ResourceGrid, Scenario, BUDGET_ERROR_PREFIX, BUDGET_REASON_CANDIDATES,
    BUDGET_REASON_DEADLINE, LINREG_CG,
};
use systemds::opt::{gdf, resource};
use systemds::serve::{serve_lines, ServeOptions, ServeState};
use systemds::util::prop::forall;

// ---------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------

fn state(threads: usize) -> ServeState {
    ServeState::new(&ServeOptions { threads, ..Default::default() })
        .expect("default serve state boots")
}

/// Per-test scratch file under a pid-unique directory, so concurrent
/// test binaries never race on the same artifact paths.
fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sysds_serve_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create serve test dir");
    dir.join(name)
}

/// Extract `key=` from a rendered response line.
fn field<'a>(resp: &'a str, key: &str) -> Option<&'a str> {
    resp.split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .find(|(k, _)| *k == key)
        .map(|(_, v)| v)
}

// ---------------------------------------------------------------------
// Golden protocol transcript
// ---------------------------------------------------------------------

/// The fixed request script. Budgeted lines only use bounds whose
/// outcome is deterministic: `budget_candidates=1` (clock-free; every
/// multi-candidate batch trips) and `budget_ms=0` (the deadline is
/// already in the past when the first check runs).
const TRANSCRIPT: &[&str] = &[
    "# serve golden transcript — regenerate: rm tests/golden/serve_transcript.txt",
    "cmd=stats id=s0",
    "cmd=optimize id=o1 scenario=xs",
    "cmd=optimize id=o2 scenario=xs",
    "cmd=optimize id=o3 scenario=xl1 script=cg iters=5",
    "cmd=sweep id=w1 scenario=xs heaps=512,2048",
    "cmd=gdf id=g1 scenario=xs script=cg iters=2",
    "cmd=verify id=v1 scenario=xs",
    "cmd=verify id=v2 scenario=xs backend=spark script=cg iters=2",
    "what is this",
    "cmd=optimize",
    "cmd=bogus id=e1 scenario=xs",
    "cmd=optimize id=e2 scenario=atlantis",
    "cmd=optimize id=e3 scenario=xs iters=zero",
    "cmd=optimize id=e4 scenario=xs flavor=red",
    "cmd=optimize id=e5 scenario=xs scenario=xs",
    "cmd=gdf id=b1 scenario=xs script=cg iters=2 budget_candidates=1",
    "cmd=gdf id=b2 scenario=xs script=cg iters=2 budget_ms=0",
    "cmd=sweep id=b3 scenario=xs budget_ms=0",
    "cmd=stats id=s1",
];

/// Stats-only fields whose values are inherently volatile (wall-clock
/// latencies, shared-cache race outcomes, host thread count). Every
/// other response byte must be stable across runs and `--threads`.
const VOLATILE_KEYS: &[&str] = &[
    "cache_hits",
    "cache_misses",
    "cache_hit_rate",
    "cache_entries",
    "p50_us",
    "p99_us",
    "threads",
];

fn normalize(line: &str) -> String {
    line.split_whitespace()
        .map(|tok| match tok.split_once('=') {
            Some((k, _)) if VOLATILE_KEYS.contains(&k) => format!("{k}=_"),
            _ => tok.to_string(),
        })
        .collect::<Vec<_>>()
        .join(" ")
}

/// Run the transcript through the stdin/stdout transport
/// ([`serve_lines`]) on a fresh state and return normalized response
/// lines.
fn run_transcript(threads: usize) -> Vec<String> {
    let state = state(threads);
    let input = TRANSCRIPT.join("\n");
    let mut out: Vec<u8> = Vec::new();
    serve_lines(&state, std::io::Cursor::new(input), &mut out).expect("in-memory serve session");
    String::from_utf8(out)
        .expect("responses are utf-8")
        .lines()
        .map(normalize)
        .collect()
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../tests/golden/serve_transcript.txt")
}

/// One response line per non-comment request line, byte-stable across
/// thread counts, matching the checked-in snapshot (blessed on first
/// run).
#[test]
fn golden_transcript_is_byte_stable_across_threads() {
    let t1 = run_transcript(1);
    let comments =
        TRANSCRIPT.iter().filter(|l| l.trim().is_empty() || l.trim().starts_with('#')).count();
    assert_eq!(
        t1.len(),
        TRANSCRIPT.len() - comments,
        "exactly one response per non-comment request line"
    );
    let t4 = run_transcript(4);
    assert_eq!(t1, t4, "responses must be byte-stable across --threads");

    let rendered = t1.join("\n") + "\n";
    let path = golden_path();
    if !path.exists() {
        std::fs::create_dir_all(path.parent().expect("golden dir")).expect("create golden dir");
        std::fs::write(&path, &rendered).expect("write golden transcript");
        eprintln!("blessed new golden snapshot: {}", path.display());
        return;
    }
    let expected = std::fs::read_to_string(&path).expect("read golden transcript");
    assert_eq!(
        rendered,
        expected,
        "serve transcript diverged from {} — delete the snapshot and re-run to re-bless",
        path.display()
    );
}

/// Structural pins that hold regardless of snapshot state: error codes,
/// ladder levels and downgrade trails land where the protocol promises.
#[test]
fn transcript_structure_pins() {
    let resp = run_transcript(1);
    let by_id = |id: &str| -> &String {
        resp.iter()
            .find(|l| field(l, "id") == Some(id))
            .unwrap_or_else(|| panic!("response for id={id}"))
    };

    // Repeated identical request: identical bitwise answer.
    let o1 = by_id("o1");
    let o2 = by_id("o2");
    assert_eq!(field(o1, "cost_bits"), field(o2, "cost_bits"));
    assert_eq!(field(o1, "backend"), field(o2, "backend"));
    for id in ["o1", "o2", "o3", "w1", "g1"] {
        let l = by_id(id);
        assert_eq!(field(l, "ok"), Some("true"), "{l}");
        assert_eq!(field(l, "level"), Some("full"), "{l}");
        assert_eq!(field(l, "downgrade"), Some("none"), "{l}");
    }
    for (id, code) in [
        ("e1", "unknown-cmd"),
        ("e2", "unknown-scenario"),
        ("e3", "bad-value"),
        ("e4", "unknown-key"),
        ("e5", "duplicate-key"),
    ] {
        let l = by_id(id);
        assert_eq!(field(l, "ok"), Some("false"), "{l}");
        assert_eq!(field(l, "code"), Some(code), "{l}");
    }
    // Over-budget optimizer requests fail soft: terminal cached rung,
    // machine-readable reason trail, still a full answer.
    for (id, reason) in [
        ("b1", "candidates,candidates"),
        ("b2", "deadline,deadline"),
        ("b3", "deadline,deadline"),
    ] {
        let l = by_id(id);
        assert_eq!(field(l, "ok"), Some("true"), "{l}");
        assert_eq!(field(l, "level"), Some("cached"), "{l}");
        assert_eq!(field(l, "downgrade"), Some(reason), "{l}");
        assert!(field(l, "cost_bits").is_some(), "{l}");
    }
    // b3's scenario/script was decided by o1 at full fidelity, so the
    // cached rung answers from the argmin table; b1/b2's key was never
    // decided, so they fall back to the un-budgeted default plan.
    assert_eq!(field(by_id("b3"), "source"), Some("argmin-table"));
    assert_eq!(field(by_id("b3"), "cost_bits"), field(by_id("o1"), "cost_bits"));
    assert_eq!(field(by_id("b1"), "source"), Some("default-plan"));
    assert_eq!(field(by_id("b2"), "source"), Some("default-plan"));
    assert_eq!(field(by_id("b1"), "cost_bits"), field(by_id("b2"), "cost_bits"));

    // The trailing stats response saw every earlier request.
    let s1 = by_id("s1");
    let n = (TRANSCRIPT.len() - 1) as u64; // minus the comment line
    assert_eq!(field(s1, "requests"), Some(format!("{}", n - 1).as_str()), "{s1}");
    // "what is this", the cmd-less line, and e1..e5.
    assert_eq!(field(s1, "errors"), Some("7"), "{s1}");
    assert_eq!(field(s1, "downgraded"), Some("3"), "{s1}");
    assert_eq!(field(s1, "downgrade_deadline"), Some("4"), "{s1}");
    assert_eq!(field(s1, "downgrade_candidates"), Some("2"), "{s1}");
}

// ---------------------------------------------------------------------
// Concurrency stress
// ---------------------------------------------------------------------

/// Request mix used by the stress and interleaving tests: no `stats`
/// lines (their counters are intentionally volatile), everything else
/// deterministic by design.
fn stress_requests() -> Vec<String> {
    vec![
        "cmd=optimize id=q1 scenario=xs".to_string(),
        "cmd=optimize id=q2 scenario=xl1".to_string(),
        "cmd=optimize id=q3 scenario=xl1 script=cg iters=3".to_string(),
        "cmd=verify id=q4 scenario=xs backend=spark".to_string(),
        "cmd=gdf id=q5 scenario=xs script=cg iters=2".to_string(),
    ]
}

/// N concurrent clients of one shared state each see responses bitwise
/// identical to a serial client on a fresh state — the shared memo and
/// cache are invisible in response bytes.
#[test]
fn concurrent_clients_match_serial_bitwise() {
    let reqs = stress_requests();
    let serial = state(1);
    let baseline: Vec<String> =
        reqs.iter().map(|r| serial.handle_line(r).expect("response")).collect();

    let shared = Arc::new(state(2));
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let shared = Arc::clone(&shared);
            let reqs = reqs.clone();
            std::thread::spawn(move || {
                reqs.iter()
                    .map(|r| shared.handle_line(r).expect("response"))
                    .collect::<Vec<String>>()
            })
        })
        .collect();
    for h in handles {
        let got = h.join().expect("client thread");
        assert_eq!(got, baseline, "concurrent responses must match the serial run bitwise");
    }

    let stats = shared.stats_snapshot();
    assert_eq!(stats.requests, (reqs.len() * 4) as u64);
    assert_eq!(stats.errors, 0);
}

// ---------------------------------------------------------------------
// Interleaving property
// ---------------------------------------------------------------------

/// Shuffling the request order never changes any request's outcome
/// tuple (ok, level/code, downgrade trail, cost bits). Budgeted
/// requests use scenario × script × iters keys no full-fidelity request
/// writes, so even the terminal cached rung is order-independent.
#[test]
fn interleaving_order_never_changes_outcomes() {
    let reqs: Vec<String> = vec![
        "cmd=optimize id=f1 scenario=xs".to_string(),
        "cmd=optimize id=f2 scenario=xl1".to_string(),
        "cmd=gdf id=b1 scenario=xl2 script=cg iters=3 budget_candidates=1".to_string(),
        "cmd=sweep id=b2 scenario=xl3 budget_ms=0".to_string(),
        "cmd=flying id=e1 scenario=xs".to_string(),
        "cmd=optimize id=e2 scenario=xs budget_candidates=zero".to_string(),
    ];
    let outcome = |line: &str| -> (String, String, String, String) {
        (
            field(line, "ok").unwrap_or("").to_string(),
            field(line, "level").or_else(|| field(line, "code")).unwrap_or("").to_string(),
            field(line, "downgrade").unwrap_or("").to_string(),
            field(line, "cost_bits").unwrap_or("").to_string(),
        )
    };
    let run = |order: &[usize]| -> Vec<(String, (String, String, String, String))> {
        let st = state(1);
        let mut got: Vec<_> = order
            .iter()
            .map(|&i| {
                let resp = st.handle_line(&reqs[i]).expect("response");
                (field(&resp, "id").expect("id echoed").to_string(), outcome(&resp))
            })
            .collect();
        got.sort();
        got
    };

    let baseline = run(&(0..reqs.len()).collect::<Vec<_>>());
    forall(
        6,
        0xC0FFEE,
        |rng| {
            // Fisher–Yates over the request indices.
            let mut order: Vec<usize> = (0..reqs.len()).collect();
            for i in (1..order.len()).rev() {
                order.swap(i, rng.below(i as u64 + 1) as usize);
            }
            order
        },
        |order| {
            let got = run(order);
            if got == baseline {
                Ok(())
            } else {
                Err(format!("outcomes changed under reordering:\n{got:?}\nvs\n{baseline:?}"))
            }
        },
    );
}

// ---------------------------------------------------------------------
// Warm-start regressions
// ---------------------------------------------------------------------

/// A daemon booted from a cost-cache snapshot answers its first request
/// with cache hits > 0 and reproduces the cold argmin bitwise.
#[test]
fn warm_cache_boot_replays_cold_argmin_with_hits() {
    let cold = state(1);
    let req = "cmd=optimize id=c scenario=xl1";
    let cold_resp = cold.handle_line(req).expect("cold response");
    assert_eq!(field(&cold_resp, "ok"), Some("true"));
    let cache = cold.cache().expect("cost cache is on by default");
    let snap = CacheSnapshot::from_cache(&cache);
    assert!(!snap.is_empty(), "cold run must populate the shared cache");

    let path = tmp("warm_boot.costcache");
    save_artifact(&path, &Artifact::CacheSnapshot(snap)).expect("save snapshot");

    let warm = ServeState::new(&ServeOptions {
        threads: 1,
        warm_cache: Some(path),
        ..Default::default()
    })
    .expect("warm serve state boots");
    assert!(
        warm.boot_summary().contains("warm="),
        "boot banner must report the warmed entries: {}",
        warm.boot_summary()
    );
    let before = warm.cache_stats();
    let warm_resp = warm.handle_line(req).expect("warm response");
    let after = warm.cache_stats();
    assert!(
        after.hits > before.hits,
        "first warm request must be served with cache hits (before {} / after {})",
        before.hits,
        after.hits
    );
    assert_eq!(field(&warm_resp, "cost_bits"), field(&cold_resp, "cost_bits"));
    assert_eq!(field(&warm_resp, "backend"), field(&cold_resp, "backend"));
}

/// `--warm-cache` under `--no-cost-cache` is a boot-time contradiction,
/// and a wrong-kind artifact is a diagnostic, not a panic.
#[test]
fn warm_cache_boot_diagnostics() {
    let err = ServeState::new(&ServeOptions {
        no_cost_cache: true,
        warm_cache: Some(tmp("unused.costcache")),
        ..Default::default()
    })
    .expect_err("contradictory boot must fail");
    assert!(err.contains("--no-cost-cache"), "{err}");

    let err = ServeState::new(&ServeOptions {
        warm_cache: Some(tmp("missing.costcache")),
        ..Default::default()
    })
    .expect_err("missing snapshot must fail");
    assert!(!err.is_empty());
}

/// A daemon booted under a calibration profile runs every request with
/// the calibrated constants — deterministically so.
#[test]
fn profile_boot_serves_under_calibrated_constants() {
    let opts = CalibrateOptions {
        seed: 7,
        quick: true,
        threads: 1,
        mode: MeasureMode::Simulated { noise: 0.0 },
        ..Default::default()
    };
    let report = calibrate(&opts).expect("simulated calibration");
    let profile = CalibrationProfile::from_report(&report, &opts);
    let path = tmp("boot.profile");
    save_artifact(&path, &Artifact::Profile(profile)).expect("save profile");

    let boot = || {
        ServeState::new(&ServeOptions {
            threads: 1,
            profile: Some(path.clone()),
            ..Default::default()
        })
        .expect("profile serve state boots")
    };
    let a = boot();
    assert!(a.boot_summary().contains("calibrated"), "{}", a.boot_summary());
    let ra = a.handle_line("cmd=optimize id=p scenario=xs").expect("response");
    assert_eq!(field(&ra, "ok"), Some("true"));
    let rb = boot().handle_line("cmd=optimize id=p scenario=xs").expect("response");
    assert_eq!(ra, rb, "calibrated answers must be deterministic across boots");
}

// ---------------------------------------------------------------------
// One-shot budget regressions (the `--budget-ms` / `--budget-candidates`
// CLI path: Evaluator::set_budget + the cooperative checks in
// opt/evaluate.rs)
// ---------------------------------------------------------------------

fn xs_cg_gdf_spec() -> GdfSpec {
    let mut spec = GdfSpec::linreg_cg(DataScenario::from(&Scenario::xs()), 2);
    spec.threads = 1;
    spec
}

/// A candidate budget of 1 trips the gdf run with the stable
/// machine-readable error, every time.
#[test]
fn gdf_candidate_budget_fails_soft_and_deterministically() {
    let mut reasons = Vec::new();
    for _ in 0..3 {
        let mut eval = Evaluator::new(1);
        eval.set_budget(Some(Budget::new(None, Some(1))));
        let err = gdf::optimize_with(&xs_cg_gdf_spec(), &mut eval)
            .expect_err("budget of 1 candidate cannot cover a gdf enumeration");
        assert!(err.starts_with(BUDGET_ERROR_PREFIX), "{err}");
        reasons.push(budget_error_reason(&err).expect("budget reason"));
    }
    assert_eq!(reasons, vec![BUDGET_REASON_CANDIDATES; 3], "same reason code every run");
}

/// An already-expired wall-clock budget trips the resource grid before
/// any candidate is compiled.
#[test]
fn resource_deadline_budget_fails_soft() {
    let grid = ResourceGrid::new(
        LINREG_CG,
        linreg_cg_args(2),
        DataScenario::from(&Scenario::xs()),
    );
    let mut eval = Evaluator::new(1);
    eval.set_budget(Some(Budget::new(Some(0), None)));
    let err = resource::optimize_grid_with(&grid, &mut eval)
        .expect_err("expired deadline must trip the run");
    assert_eq!(budget_error_reason(&err), Some(BUDGET_REASON_DEADLINE), "{err}");
    assert_eq!(eval.distinct_plans(), 0, "no plan may be compiled after expiry");
}

/// A generous budget is invisible: the gdf run produces bitwise the
/// same report as an unbudgeted one.
#[test]
fn generous_budget_leaves_results_bitwise_unchanged() {
    let spec = xs_cg_gdf_spec();
    let mut plain = Evaluator::new(1);
    let a = gdf::optimize_with(&spec, &mut plain).expect("unbudgeted gdf run");

    let mut budgeted = Evaluator::new(1);
    budgeted.set_budget(Some(Budget::new(Some(3_600_000), Some(1_000_000))));
    let b = gdf::optimize_with(&spec, &mut budgeted).expect("generously budgeted gdf run");

    assert_eq!(a.candidates.len(), b.candidates.len());
    assert_eq!(a.best().label(), b.best().label());
    assert_eq!(
        a.best().cost_secs.to_bits(),
        b.best().cost_secs.to_bits(),
        "budget plumbing must not perturb costs"
    );
}
