//! Integration tests for the static plan verifier (`src/analysis/`):
//! every bundled scenario × backend × script must verify with zero
//! error-severity diagnostics, plan generation must stay free of
//! leaked-temp / dead-instruction lint (the `gen_pred` rmvar regression),
//! injected faults must be caught by the right pass, and the verify
//! report for the LinReg CG plan is pinned by golden snapshots under
//! `tests/golden/` (bless-on-first-run, same convention as
//! `tests/golden.rs`).

use std::path::PathBuf;

use systemds::analysis::{self, Pass, Severity};
use systemds::api::{
    compile_with_meta, linreg_cg_args, verify_plan, CompileOptions, CompiledProgram, ExecBackend,
    Scenario, LINREG_CG,
};
use systemds::conf::{ClusterConfig, CostConstants, SystemConfig};
use systemds::ir::{AggDir, AggOp, Lit, ValueType};
use systemds::matrix::{Format, MatrixCharacteristics};
use systemds::rtprog::{CpInst, CpOp, Instr, Operand, RtBlock, RtProgram};

fn compile(s: &Scenario, backend: ExecBackend, script: &str) -> (CompiledProgram, CompileOptions) {
    let opts = CompileOptions { backend, ..Default::default() };
    let compiled = match script {
        "cg" => compile_with_meta(LINREG_CG, &linreg_cg_args(20), &s.meta(opts.cfg.blocksize), &opts)
            .expect("LinReg CG compiles"),
        _ => s.compile(&opts),
    };
    (compiled, opts)
}

/// Every bundled scenario, on every backend, for both bundled scripts,
/// verifies with zero error-severity diagnostics — the analyzer's
/// double-entry checks agree with plan generation and the cost model.
#[test]
fn all_bundled_plans_verify_without_errors() {
    for s in Scenario::all() {
        for backend in ExecBackend::all() {
            for script in ["ds", "cg"] {
                let (compiled, opts) = compile(&s, backend, script);
                let r = verify_plan(&compiled, &opts);
                assert!(
                    r.is_clean(),
                    "{}/{}/{}: expected no errors:\n{}",
                    s.name,
                    backend.name(),
                    script,
                    r.render()
                );
                assert_eq!(r.blocks, compiled.runtime.blocks.len());
            }
        }
    }
}

/// Plan generation frees every `_mVar` temp it materializes — including
/// predicate sub-expressions (`gen_pred` regression: a matrix-valued
/// While/If predicate used to leak its intermediates) — and never emits
/// an instruction whose result is unconsumed.
#[test]
fn bundled_plans_have_no_leaked_temps_or_dead_instructions() {
    for s in Scenario::all() {
        for backend in ExecBackend::all() {
            for script in ["ds", "cg"] {
                let (compiled, opts) = compile(&s, backend, script);
                let r = verify_plan(&compiled, &opts);
                for d in &r.diagnostics {
                    assert!(
                        !d.message.contains("leak candidate")
                            && !d.message.contains("dead instruction"),
                        "{}/{}/{}: {}",
                        s.name,
                        backend.name(),
                        script,
                        d.render()
                    );
                }
            }
        }
    }
}

fn generic(insts: Vec<Instr>) -> RtProgram {
    RtProgram {
        blocks: vec![RtBlock::Generic { insts, lines: (1, 1), recompile: false }],
        funcs: Default::default(),
    }
}

fn verify_rt(rt: &RtProgram, k: &CostConstants, backend: ExecBackend) -> analysis::VerifyReport {
    analysis::verify(rt, &SystemConfig::default(), &ClusterConfig::paper_cluster(), k, backend)
}

/// Injected fault 1 (dataflow): an instruction reading a variable no one
/// defined is caught by the dataflow pass with error severity.
#[test]
fn injected_use_before_def_is_caught_by_the_dataflow_pass() {
    let rt = generic(vec![Instr::Cp(CpInst {
        op: CpOp::Transpose,
        inputs: vec![Operand::Mat("X".into())],
        output: Operand::Mat("_mVar1".into()),
    })]);
    let r = verify_rt(&rt, &CostConstants::default(), ExecBackend::Cp);
    assert!(
        r.diagnostics.iter().any(|d| d.pass == Pass::Dataflow
            && d.severity == Severity::Error
            && d.message.contains("undefined variable 'X'")),
        "{}",
        r.render()
    );
}

/// Injected fault 2 (shape): declared output metadata contradicting the
/// operator's dimension rule is caught by the shape pass.
#[test]
fn injected_shape_contradiction_is_caught_by_the_shape_pass() {
    let cv = |var: &str, rows: i64, cols: i64| Instr::CreateVar {
        var: var.into(),
        path: format!("scratch/{var}"),
        temp: true,
        format: Format::BinaryBlock,
        mc: MatrixCharacteristics::dense(rows, cols, 1000),
    };
    let rt = generic(vec![
        cv("X", 100, 10),
        cv("_mVar1", 100, 10), // transpose of 100x10 must be 10x100
        Instr::Cp(CpInst {
            op: CpOp::Transpose,
            inputs: vec![Operand::Mat("X".into())],
            output: Operand::Mat("_mVar1".into()),
        }),
        Instr::RmVar { vars: vec!["X".into(), "_mVar1".into()] },
    ]);
    let r = verify_rt(&rt, &CostConstants::default(), ExecBackend::Cp);
    assert!(
        r.diagnostics.iter().any(|d| d.pass == Pass::Shape
            && d.severity == Severity::Error
            && d.message.contains("shape mismatch")),
        "{}",
        r.render()
    );
}

/// Injected fault 3 (cost invariants): a calibration profile with zero
/// HDFS bandwidth prices the persistent read at +inf, which the cost
/// audit reports as an error.
#[test]
fn injected_non_finite_cost_is_caught_by_the_cost_pass() {
    let k = CostConstants { hdfs_read_binaryblock: 0.0, ..CostConstants::default() };
    let rt = generic(vec![
        Instr::CreateVar {
            var: "X".into(),
            path: "data/X".into(),
            temp: false,
            format: Format::BinaryBlock,
            mc: MatrixCharacteristics::dense(10_000, 1_000, 1_000),
        },
        Instr::Cp(CpInst {
            op: CpOp::AggUnary(AggOp::Sum, AggDir::All),
            inputs: vec![Operand::Mat("X".into())],
            output: Operand::Scalar("s".into(), ValueType::Double),
        }),
    ]);
    let r = verify_rt(&rt, &k, ExecBackend::Cp);
    assert!(
        r.diagnostics.iter().any(|d| d.pass == Pass::CostInvariants
            && d.severity == Severity::Error
            && d.message.contains("not finite")),
        "{}",
        r.render()
    );
}

/// Diagnostics carry the structural block hash of the enclosing
/// top-level block, so a finding survives re-compilation of an
/// identical plan bit-for-bit.
#[test]
fn diagnostics_are_stable_across_recompilation() {
    let s = Scenario::xl1();
    let (a, opts) = compile(&s, ExecBackend::Mr, "cg");
    let (b, _) = compile(&s, ExecBackend::Mr, "cg");
    let ra = verify_plan(&a, &opts);
    let rb = verify_plan(&b, &opts);
    assert_eq!(ra.render(), rb.render());
    assert_eq!(ra.summary(), rb.summary());
}

/// `AssignVar`-only scalar plans (no matrices at all) verify clean —
/// the analyzer does not require matrix metadata to exist.
#[test]
fn scalar_only_plan_verifies_clean() {
    let rt = generic(vec![Instr::AssignVar { lit: Lit::Int(7), var: "n".into() }]);
    let r = verify_rt(&rt, &CostConstants::default(), ExecBackend::Cp);
    assert!(r.diagnostics.is_empty(), "{}", r.render());
}

// ---------------------------------------------------------------------
// Golden snapshots: summary + rendered diagnostics for the LinReg CG
// XL1 plan, one per backend. Bless-on-first-run; regenerate with
// `rm tests/golden/verify_*.txt && cargo test --test verify`.
// ---------------------------------------------------------------------

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../tests/golden")
}

fn verify_text(backend: ExecBackend) -> String {
    let (compiled, opts) = compile(&Scenario::xl1(), backend, "cg");
    let r = verify_plan(&compiled, &opts);
    let text = format!("{}\n{}", r.summary(), r.render());
    systemds::util::fmt::normalize_scratch_pid(&text)
}

fn check_golden(backend: ExecBackend) {
    let first = verify_text(backend);
    let second = verify_text(backend);
    assert_eq!(first, second, "{}: verify output must be deterministic", backend.name());

    let dir = golden_dir();
    let path = dir.join(format!("verify_linreg_cg_{}.txt", backend.name()));
    if !path.exists() {
        std::fs::create_dir_all(&dir).expect("create tests/golden");
        std::fs::write(&path, &first).expect("write golden snapshot");
        eprintln!("blessed new golden snapshot: {}", path.display());
        return;
    }
    let expected = std::fs::read_to_string(&path).expect("read golden snapshot");
    assert_eq!(
        first,
        expected,
        "{}: verify output diverged from {} — delete the snapshot and re-run to re-bless",
        backend.name(),
        path.display()
    );
}

#[test]
fn golden_verify_linreg_cg_cp() {
    check_golden(ExecBackend::Cp);
}

#[test]
fn golden_verify_linreg_cg_mr() {
    check_golden(ExecBackend::Mr);
}

#[test]
fn golden_verify_linreg_cg_spark() {
    check_golden(ExecBackend::Spark);
}
