//! Integration tests for the online calibration loop (`src/feedback/`):
//! the deterministic simulated mode end to end, the safeguarded robust
//! regression's invariants, and the cost cache's constants fingerprint.
//!
//! Everything here runs [`MeasureMode::Simulated`], so every assertion is
//! bitwise-reproducible on any machine at any load.

use std::collections::HashMap;

use systemds::api::{compile_with_meta, ClusterConfigOpt, CompileOptions, LINREG_DS};
use systemds::conf::CostConstants;
use systemds::cost::cache::{program_hashes, CostCache};
use systemds::cost::{cost_total, cost_total_cached};
use systemds::feedback::runner::cluster_for;
use systemds::feedback::{
    calibrate, fit, repredict, simulator_truth, BlockClass, BlockRecord, CalibrateOptions,
    CalibrationCase, CostBreakdown, MeasureMode,
};
use systemds::ir::build::StaticMeta;
use systemds::matrix::{Format, MatrixCharacteristics};
use systemds::rtprog::ExecBackend;
use systemds::util::rng::Rng;

fn simulated_opts(seed: u64, threads: usize) -> CalibrateOptions {
    CalibrateOptions {
        seed,
        quick: true,
        threads,
        mode: MeasureMode::Simulated { noise: 0.0 },
        ..Default::default()
    }
}

/// The tentpole acceptance test: calibrating against the in-process
/// runtime's profile must flip the backend argmin on the bundled
/// re-optimization scenario — the Hadoop-calibrated defaults pick the
/// single-threaded CP plan (distributed startup latency dominates), the
/// calibrated constants pick a distributed plan (latency collapsed to
/// milliseconds, reads and exec divided across 8 slots).
#[test]
fn reoptimization_flips_the_backend_argmin_after_calibration() {
    let report = calibrate(&simulated_opts(42, 0)).expect("simulated calibration");
    assert!(!report.corrections.is_identity(), "fit found no corrections");
    assert!(
        report.after.geo_mean < report.before.geo_mean,
        "calibration should improve accuracy on the bundled cases: {} -> {}",
        report.before.geo_mean,
        report.after.geo_mean
    );
    let reopt = &report.reopt;
    assert_eq!(reopt.choices.len(), ExecBackend::all().len());
    for c in &reopt.choices {
        assert!(c.before_secs.is_finite() && c.before_secs > 0.0, "{:?}", c.backend);
        assert!(c.after_secs.is_finite() && c.after_secs > 0.0, "{:?}", c.backend);
    }
    assert_eq!(
        reopt.argmin_before,
        ExecBackend::Cp,
        "defaults must pick CP (distributed latency dominates): {reopt:?}"
    );
    assert_ne!(
        reopt.argmin_after,
        ExecBackend::Cp,
        "calibrated constants must pick a distributed backend: {reopt:?}"
    );
    assert!(reopt.flipped());
}

/// Calibration is bitwise-deterministic given a seed — in particular it
/// must not depend on the thread count, which sizes real execution but
/// never the simulated measurement or the (sequential) fit.
#[test]
fn simulated_calibration_is_bitwise_deterministic_across_thread_counts() {
    let a = calibrate(&simulated_opts(7, 1)).unwrap();
    let b = calibrate(&simulated_opts(7, 8)).unwrap();
    assert_eq!(a.corrections, b.corrections);
    assert_eq!(a.calibrated, b.calibrated, "calibrated constants differ");
    assert_eq!(a.before, b.before);
    assert_eq!(a.after, b.after);
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.hash, rb.hash);
        assert_eq!(ra.predicted_secs.to_bits(), rb.predicted_secs.to_bits());
        assert_eq!(ra.measured_secs.to_bits(), rb.measured_secs.to_bits());
    }
    assert_eq!(a.reopt.argmin_before, b.reopt.argmin_before);
    assert_eq!(a.reopt.argmin_after, b.reopt.argmin_after);
    // and an independent rerun with the same seed reproduces everything
    let c = calibrate(&simulated_opts(7, 1)).unwrap();
    assert_eq!(a.calibrated, c.calibrated);
}

/// A second fit on the records the first fit already corrected is the
/// identity: the calibration loop cannot oscillate.
#[test]
fn second_fit_on_corrected_records_is_a_fixpoint() {
    let report = calibrate(&simulated_opts(42, 0)).unwrap();
    let c1 = fit(&report.records, 42);
    assert_eq!(c1, report.corrections, "report carries the fit of its own records");
    let corrected = repredict(&report.records, &c1);
    let c2 = fit(&corrected, 42);
    assert!(c2.is_identity(), "second pass drifted: {c2:?}");
    // and a third pass over twice-repredicted records stays put
    let c3 = fit(&repredict(&corrected, &c2), 42);
    assert!(c3.is_identity());
}

/// Property: whatever the records — including non-finite, zero and
/// negative measurements — the fitted corrections applied to valid
/// constants always produce constants that pass `validate()`.
#[test]
fn fitted_corrections_always_yield_valid_constants() {
    let k0 = CostConstants::default();
    let mut rng = Rng::new(0xC0FFEE);
    for trial in 0..200 {
        let n = (rng.below(40) + 1) as usize;
        let records: Vec<BlockRecord> = (0..n)
            .map(|i| {
                let class = BlockClass::ALL[rng.below(5) as usize];
                let mut breakdown = CostBreakdown::default();
                // log-uniform magnitudes across 24 decades, plus
                // degenerate values in some trials
                let mag = |rng: &mut Rng| 10f64.powf(rng.uniform(-12.0, 12.0));
                *breakdown.get_mut(class) = mag(&mut rng);
                let predicted_secs = breakdown.total();
                let measured_secs = match rng.below(8) {
                    0 => 0.0,
                    1 => f64::NAN,
                    2 => f64::INFINITY,
                    3 => -mag(&mut rng),
                    _ => mag(&mut rng),
                };
                BlockRecord {
                    hash: (trial as u64, i as u64),
                    label: String::new(),
                    predicted_secs,
                    measured_secs,
                    breakdown,
                }
            })
            .collect();
        let corrections = fit(&records, trial as u64);
        let calibrated = corrections.apply(&k0);
        calibrated
            .validate()
            .unwrap_or_else(|e| panic!("trial {trial}: {e} (from {corrections:?})"));
    }
}

/// Regression test for the stale-cache guard: `CostConstants` participate
/// in the cache's knob fingerprint, so re-costing through a shared cache
/// after calibration rewrites the constants must produce exactly the
/// uncached result — never a replay of the pre-calibration entry.
#[test]
fn cost_cache_never_serves_stale_totals_after_constants_change() {
    let case = CalibrationCase {
        name: "linreg 4096x128",
        script: LINREG_DS,
        rows: 4096,
        cols: 128,
        heap_mb: 0.12,
        iters: 0,
    };
    let cc = cluster_for(8, &case);
    let mut args = HashMap::new();
    args.insert(1, "stale/X".to_string());
    args.insert(2, "stale/y".to_string());
    args.insert(3, "0".to_string());
    args.insert(4, "stale/out".to_string());

    for backend in ExecBackend::all() {
        let opts =
            CompileOptions { cc: ClusterConfigOpt(cc.clone()), backend, ..Default::default() };
        let meta = StaticMeta::default()
            .with(
                "stale/X",
                MatrixCharacteristics::dense(case.rows as i64, case.cols as i64, opts.cfg.blocksize),
                Format::BinaryBlock,
            )
            .with(
                "stale/y",
                MatrixCharacteristics::dense(case.rows as i64, 1, opts.cfg.blocksize),
                Format::BinaryBlock,
            );
        let rt = compile_with_meta(case.script, &args, &meta, &opts).unwrap().runtime;
        let hashes = program_hashes(&rt);
        let cache = CostCache::default();

        let k1 = CostConstants::default();
        // the calibrated profile rewrites every constant group, including
        // the flop_efficiency field added for calibration
        let k2 = simulator_truth();
        let k3 = CostConstants { flop_efficiency: 2.0, ..CostConstants::default() };

        // warm the cache under k1, then re-cost under k2 and k3: every
        // cached total must match its uncached costing bitwise
        let tag = backend.name();
        let u1 = cost_total(&rt, &opts.cfg, &cc, &k1);
        let c1 = cost_total_cached(&rt, &hashes, &opts.cfg, &cc, &k1, &cache);
        assert_eq!(u1.to_bits(), c1.to_bits(), "{tag}: cold");
        for (name, k) in [("truth", &k2), ("flop_eff", &k3)] {
            let u = cost_total(&rt, &opts.cfg, &cc, k);
            let c = cost_total_cached(&rt, &hashes, &opts.cfg, &cc, k, &cache);
            assert_eq!(u.to_bits(), c.to_bits(), "{tag}/{name}: stale cache hit");
            assert_ne!(u.to_bits(), u1.to_bits(), "{tag}/{name}: constants must move the cost");
        }
        // and the original constants still replay their own entry
        let c1_again = cost_total_cached(&rt, &hashes, &opts.cfg, &cc, &k1, &cache);
        assert_eq!(u1.to_bits(), c1_again.to_bits(), "{tag}: warm replay");
    }
}

/// Regression test for the scratch-collision bug: execute-mode
/// calibration used the fixed path `$TMPDIR/sysds_feedback`, so two
/// concurrent runs raced on each other's spill files and the directory
/// was never removed. Defaulted scratch is now unique per run (pid +
/// seed + counter) and cleaned up on success — two concurrent executed
/// calibrations must both succeed and leave no per-run directory behind.
#[test]
fn concurrent_executed_calibrations_use_disjoint_scratch_and_clean_up() {
    let opts = |seed| CalibrateOptions {
        seed,
        quick: true,
        threads: 1,
        mode: MeasureMode::Execute,
        ..Default::default()
    };
    let a = std::thread::spawn({
        let o = opts(11);
        move || calibrate(&o)
    });
    let b = std::thread::spawn({
        let o = opts(13);
        move || calibrate(&o)
    });
    a.join().expect("thread A").expect("calibration A");
    b.join().expect("thread B").expect("calibration B");

    // both per-run scratch directories were removed on success (other
    // processes may own entries under the shared base — only this
    // process's seed-11/seed-13 runs are ours to assert on)
    let base = std::env::temp_dir().join("sysds_feedback");
    if base.is_dir() {
        let pid = std::process::id();
        for entry in std::fs::read_dir(&base).expect("read scratch base") {
            let name = entry.expect("dir entry").file_name().to_string_lossy().into_owned();
            assert!(
                !name.starts_with(&format!("run_{pid}_11_"))
                    && !name.starts_with(&format!("run_{pid}_13_")),
                "leftover per-run scratch dir: {name}"
            );
        }
    }
}

/// An explicit `scratch` override is used as given and never cleaned up:
/// the caller owns it (post-mortems, shared caches between runs).
#[test]
fn explicit_scratch_override_is_used_and_kept() {
    let dir = std::env::temp_dir().join(format!("sysds_scratch_override_{}", std::process::id()));
    let opts = CalibrateOptions {
        seed: 5,
        quick: true,
        threads: 1,
        mode: MeasureMode::Execute,
        scratch: Some(dir.clone()),
        ..Default::default()
    };
    calibrate(&opts).expect("calibration with explicit scratch");
    assert!(dir.is_dir(), "explicit scratch must survive a successful calibration");
    std::fs::remove_dir_all(&dir).ok();
}

/// The calibrated constants move toward the simulator-truth profile the
/// simulated measurements were drawn from: job latency collapses by
/// orders of magnitude and read bandwidth rises.
#[test]
fn calibration_moves_constants_toward_the_measured_profile() {
    let report = calibrate(&simulated_opts(42, 0)).unwrap();
    let (k0, k1) = (&report.initial, &report.calibrated);
    assert!(
        k1.job_latency < k0.job_latency / 5.0,
        "job latency should collapse toward the in-process runtime: {} -> {}",
        k0.job_latency,
        k1.job_latency
    );
    // corrections stay inside the declared clamp
    for class in BlockClass::ALL {
        let s = report.corrections.get(class);
        assert!(
            (systemds::feedback::regression::MIN_SCALE..=systemds::feedback::regression::MAX_SCALE)
                .contains(&s),
            "{class:?} scale {s} out of bounds"
        );
    }
}
