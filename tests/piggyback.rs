//! Direct coverage for the piggybacking packer
//! (`rtprog/piggyback.rs`): independent MR operations merge into one
//! job, dependent operations split across jobs, and instruction order /
//! byte indices are preserved — pinning the MR path while the Spark
//! backend evolves beside it.

use systemds::ir::BinOp;
use systemds::matrix::MatrixCharacteristics;
use systemds::rtprog::piggyback::{pack, MrDep, MrNode, Phase};
use systemds::rtprog::{JobType, MrOp};

fn mc(r: i64, c: i64) -> MatrixCharacteristics {
    MatrixCharacteristics::new(r, c, 1000, -1)
}

fn node(nid: usize, op: MrOp, deps: Vec<MrDep>) -> MrNode {
    MrNode {
        nid,
        op,
        agg: None,
        phase: Phase::Map,
        job_type: JobType::Gmr,
        replicable: false,
        deps,
        broadcast: None,
        out_var: format!("_mVar{}", nid + 10),
        mc: mc(1000, 1000),
        out_needed: true,
    }
}

/// Two independent map-side operations over *different* inputs still
/// merge into a single GMR job (the shared job reads several inputs).
#[test]
fn independent_ops_merge_into_one_job() {
    let a = node(0, MrOp::Transpose, vec![MrDep::Var("X".into(), mc(100_000_000, 1000))]);
    let b = node(1, MrOp::Transpose, vec![MrDep::Var("Y".into(), mc(50_000_000, 1000))]);
    let packed = pack(&[a, b], 12, 1);
    assert_eq!(packed.jobs.len(), 1, "independent map ops share one job");
    let j = &packed.jobs[0];
    assert_eq!(j.inputs, vec!["X".to_string(), "Y".to_string()]);
    assert_eq!(j.map_insts.len(), 2);
    assert_eq!(j.outputs.len(), 2);
}

/// Three independent aggregated pipelines merge: one job, three inputs,
/// three map instructions, three aggregations.
#[test]
fn independent_aggregated_pipelines_share_one_job() {
    let mut nodes = Vec::new();
    for (i, name) in ["A", "B", "C"].iter().enumerate() {
        let mut n = node(
            i,
            MrOp::Tsmm { left: true },
            vec![MrDep::Var(name.to_string(), mc(10_000_000, 500))],
        );
        n.agg = Some(MrOp::Agg { kahan: true });
        nodes.push(n);
    }
    let packed = pack(&nodes, 12, 1);
    assert_eq!(packed.jobs.len(), 1);
    let j = &packed.jobs[0];
    assert_eq!(j.map_insts.len(), 3);
    assert_eq!(j.agg_insts.len(), 3);
    assert_eq!(j.outputs.len(), 3);
}

/// An operation consuming another's *aggregated* output cannot ride the
/// same job: the dependency forces a second job reading the
/// materialised intermediate.
#[test]
fn dependent_ops_split_across_jobs() {
    let mut producer = node(0, MrOp::Tsmm { left: true }, vec![MrDep::Var(
        "X".into(),
        mc(100_000_000, 1000),
    )]);
    producer.agg = Some(MrOp::Agg { kahan: true });
    let consumer = node(
        1,
        MrOp::ScalarBin { op: BinOp::Mul, scalar: 3.0, scalar_var: None, scalar_left: false },
        vec![MrDep::Node(0)],
    );
    let packed = pack(&[producer, consumer], 12, 1);
    assert_eq!(packed.jobs.len(), 2, "aggregated output forces a job break");
    // the first job materialises the intermediate the second reads
    assert_eq!(packed.jobs[0].outputs.len(), 1);
    assert!(
        packed.jobs[1].inputs.contains(&packed.jobs[0].outputs[0]),
        "second job must read the first job's output"
    );
    // and the dependency never runs before its producer
    assert!(packed.jobs[0].all_insts().any(|i| matches!(i.op, MrOp::Tsmm { .. })));
    assert!(packed.jobs[1].all_insts().any(|i| matches!(i.op, MrOp::ScalarBin { .. })));
}

/// A shuffle operation (cpmm) and an independent map operation do NOT
/// merge: shuffle nodes open their own MMCJ job.
#[test]
fn shuffle_nodes_get_their_own_job() {
    let mut cpmm = node(
        0,
        MrOp::Cpmm,
        vec![
            MrDep::Var("A".into(), mc(1_000, 100_000_000)),
            MrDep::Var("B".into(), mc(100_000_000, 1000)),
        ],
    );
    cpmm.phase = Phase::Shuffle;
    cpmm.job_type = JobType::Mmcj;
    let other = node(1, MrOp::Transpose, vec![MrDep::Var("C".into(), mc(10_000, 1000))]);
    let packed = pack(&[cpmm, other], 12, 1);
    assert_eq!(packed.jobs.len(), 2);
    assert_eq!(packed.jobs[0].job_type, JobType::Mmcj);
    assert_eq!(packed.jobs[1].job_type, JobType::Gmr);
}

/// Instruction order inside a job follows the node (topological) order,
/// and byte indices are assigned inputs-first then outputs in order.
#[test]
fn instruction_order_and_byte_indices_preserved() {
    let x = || MrDep::Var("X".into(), mc(100_000_000, 1000));
    let first = node(0, MrOp::Transpose, vec![x()]);
    let second = node(
        1,
        MrOp::ScalarBin { op: BinOp::Mul, scalar: 2.0, scalar_var: None, scalar_left: false },
        vec![MrDep::Node(0)],
    );
    let third = node(
        2,
        MrOp::ScalarBin { op: BinOp::Add, scalar: 1.0, scalar_var: None, scalar_left: false },
        vec![MrDep::Node(1)],
    );
    let packed = pack(&[first, second, third], 12, 1);
    assert_eq!(packed.jobs.len(), 1, "narrow map chain shares one job");
    let j = &packed.jobs[0];
    let codes: Vec<String> = j.map_insts.iter().map(|i| i.op.code()).collect();
    assert_eq!(codes, vec!["r'", "s*", "s+"], "topological order preserved");
    // byte indices: input 0, then outputs 1, 2, 3 chained in order
    assert_eq!(j.map_insts[0].inputs, vec![0]);
    assert_eq!(j.map_insts[0].output, 1);
    assert_eq!(j.map_insts[1].inputs, vec![1]);
    assert_eq!(j.map_insts[1].output, 2);
    assert_eq!(j.map_insts[2].inputs, vec![2]);
    assert_eq!(j.map_insts[2].output, 3);
}
