//! Integration tests for the global data flow optimizer (`opt/gdf.rs`):
//! a golden decision-trace + EXPLAIN-diff snapshot (stable across thread
//! counts), the argmin-vs-default property on every bundled script ×
//! backend, and the api-entry cluster-validation regression.

use std::collections::HashMap;
use std::path::PathBuf;

use systemds::api::{
    compile, compile_with_meta, linreg_cg_args, optimize_global_dataflow, ClusterConfigOpt,
    CompileOptions, DataScenario, ExecBackend, GdfSpec, Scenario, LINREG_CG, LINREG_DS,
};
use systemds::conf::{ClusterConfig, MB};
use systemds::cost;
use systemds::matrix::Format;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../tests/golden")
}

/// The reference GDF search space: LinReg CG (20 iterations) on XL1 with
/// a small deterministic axis set.
fn cg_spec(threads: usize) -> GdfSpec {
    let s = Scenario::xl1();
    let mut spec = GdfSpec::linreg_cg(DataScenario::from(&s), 20);
    spec.blocksizes = vec![1000, 2000];
    spec.formats = vec![Format::BinaryBlock];
    spec.partitions_mb = vec![32.0];
    spec.threads = threads;
    spec
}

/// The deterministic part of a GDF report: per-cut decisions plus the
/// before/after plan diff (wall time and memo flags excluded).
fn gdf_snapshot(threads: usize) -> String {
    let report = optimize_global_dataflow(&cg_spec(threads)).expect("gdf optimizes");
    format!(
        "decision trace:\n{}\nplan diff:\n{}",
        report.decision_table(),
        report.explain_diff()
    )
}

#[test]
fn golden_gdf_trace_and_diff_stable_across_thread_counts() {
    let one = gdf_snapshot(1);
    let four = gdf_snapshot(4);
    assert_eq!(one, four, "GDF trace/diff must not depend on thread count");

    // Structural pins that hold even on a fresh checkout (the snapshot
    // below self-blesses on first run, so these are the assertions that
    // always bite in CI): the trace covers the CG program's cuts, the
    // diff removes MR jobs and introduces Spark jobs, and the scratch
    // path is PID-normalised.
    assert!(one.contains("GENERIC"), "{one}");
    assert!(one.contains("FOR"), "{one}");
    assert!(one.contains("- ") && one.contains("+ "), "{one}");
    assert!(one.contains("MR-Job["), "{one}");
    assert!(one.contains("SPARK-Job["), "{one}");
    assert!(!one.contains(&format!("_p{}", std::process::id())), "{one}");

    let dir = golden_dir();
    let path = dir.join("gdf_linreg_cg_diff.txt");
    if !path.exists() {
        std::fs::create_dir_all(&dir).expect("create tests/golden");
        std::fs::write(&path, &one).expect("write golden snapshot");
        eprintln!("blessed new golden snapshot: {}", path.display());
        return;
    }
    let expected = std::fs::read_to_string(&path).expect("read golden snapshot");
    assert_eq!(
        one,
        expected,
        "GDF trace/diff diverged from {} — delete the snapshot and re-run to re-bless",
        path.display()
    );
}

/// Acceptance: on the loop-heavy CG script the optimizer must find a
/// configuration whose costed time is *strictly* better than the default
/// compiled (MR) plan — and it gets there by restructuring the plan, not
/// by touching the cluster.
#[test]
fn gdf_strictly_improves_linreg_cg_over_default_mr() {
    let r = optimize_global_dataflow(&cg_spec(0)).unwrap();
    assert!(
        r.best().cost_secs < r.baseline().cost_secs,
        "best {} !< default {}",
        r.best().cost_secs,
        r.baseline().cost_secs
    );
    assert!(r.improvement_pct() > 0.0);
    assert!(
        r.best().groups.iter().any(|&b| b != ExecBackend::Mr),
        "the winning plan must move at least one group off the default backend: {:?}",
        r.best().groups
    );
}

/// Property (satellite): the GDF argmin cost is never worse than the
/// default-plan cost, for every bundled script × default backend. The
/// reference is compiled and costed *independently* of the optimizer
/// (`compile_with_meta` + `cost_program`), so a bug that corrupts the
/// candidate set or its costs cannot satisfy the property by comparing
/// the report against itself.
#[test]
fn gdf_argmin_never_worse_than_default_for_every_script_and_backend() {
    let s = Scenario::xl1();
    let scripts: Vec<(&str, &str, HashMap<usize, String>)> = vec![
        ("ds", LINREG_DS, s.args()),
        ("cg", LINREG_CG, linreg_cg_args(5)),
    ];
    for (name, src, args) in &scripts {
        for backend in ExecBackend::all() {
            let mut spec = GdfSpec::new(*src, args.clone(), DataScenario::from(&s));
            spec.blocksizes = vec![1000];
            spec.formats = vec![Format::BinaryBlock];
            spec.partitions_mb = vec![32.0];
            spec.default_backend = backend;
            spec.threads = 2;

            // independent reference: the default plan, compiled and
            // costed outside the optimizer
            let opts = CompileOptions {
                cfg: spec.cfg.clone(),
                cc: ClusterConfigOpt(spec.cc.clone()),
                hints: spec.hints.clone(),
                backend,
            };
            let c = compile_with_meta(
                *src,
                args,
                &spec.scenario.meta(spec.cfg.blocksize),
                &opts,
            )
            .unwrap();
            let reference =
                cost::cost_program(&c.runtime, &spec.cfg, &spec.cc, &spec.constants).total;

            let r = optimize_global_dataflow(&spec).unwrap();
            assert_eq!(r.baseline, 0, "candidate 0 is the default configuration");
            assert!(
                (r.baseline().cost_secs - reference).abs() <= 1e-9 * reference.max(1.0),
                "script {name} backend {}: baseline {} != independent default cost {}",
                backend.name(),
                r.baseline().cost_secs,
                reference
            );
            assert!(
                r.best().cost_secs <= reference * (1.0 + 1e-9),
                "script {name} backend {}: best {} > default {}",
                backend.name(),
                r.best().cost_secs,
                reference
            );
        }
    }
}

/// The per-cut trace is consistent with the chosen group assignment: a
/// CP-forced cut has no distributed jobs, and the default plan's job
/// counts are reported for comparison.
#[test]
fn decision_trace_is_consistent_with_group_assignment() {
    let r = optimize_global_dataflow(&cg_spec(2)).unwrap();
    assert!(!r.trace.is_empty());
    let before: usize = r.trace.iter().map(|d| d.jobs_before).sum();
    assert!(before > 0, "the default MR plan of CG/XL1 has distributed jobs");
    for d in &r.trace {
        if d.backend == ExecBackend::Cp {
            assert_eq!(d.jobs_after, 0, "CP-forced cut cannot have jobs: {d:?}");
        }
    }
    // trace and groups are aligned with the top-level cuts
    assert_eq!(r.trace.len(), r.best().groups.len());
}

/// Regression (satellite bugfix): every public `api::` compile entry
/// routes through `ClusterConfig::validate`, so a degenerate conf
/// surfaces as a diagnostic instead of NaN costs or panics downstream.
#[test]
fn api_compile_entries_validate_cluster_config() {
    let s = Scenario::xs();
    let mut cc = ClusterConfig::paper_cluster();
    cc.cp_heap_bytes = 0.0;
    let opts = CompileOptions { cc: ClusterConfigOpt(cc), ..Default::default() };
    let err = compile_with_meta(LINREG_DS, &s.args(), &s.meta(1000), &opts).unwrap_err();
    assert!(err.contains("cp_heap_bytes"), "{err}");

    // `compile` (the .mtd sidecar path) hits the same validation before
    // touching the filesystem
    let mut cc = ClusterConfig::paper_cluster();
    cc.k_local = 0;
    let opts = CompileOptions { cc: ClusterConfigOpt(cc), ..Default::default() };
    let err = compile(LINREG_DS, &s.args(), &opts).unwrap_err();
    assert!(err.contains("k_local"), "{err}");
}

/// A single-threaded local cluster must stay valid under the new
/// validation routing (its reducer-slot count is floored at 1).
#[test]
fn single_thread_local_cluster_still_compiles() {
    let cc = ClusterConfig::local(1, 512.0 * MB);
    cc.validate().expect("local(1) validates");
    let s = Scenario::xs();
    let opts = CompileOptions { cc: ClusterConfigOpt(cc), ..Default::default() };
    compile_with_meta(LINREG_DS, &s.args(), &s.meta(1000), &opts).expect("compiles");
}
