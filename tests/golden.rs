//! Golden-output tests for `repro explain` on the LinReg CG script, one
//! snapshot per execution backend (CP, MR, Spark), under `tests/golden/`.
//!
//! Each test renders the runtime EXPLAIN twice (asserting in-process
//! determinism), normalises the process-id scratch path, and compares
//! against the checked-in snapshot. A missing snapshot is written on
//! first run (bless-on-first-run), so regenerating after an intentional
//! plan change is `rm tests/golden/*.txt && cargo test --test golden`.

use std::path::PathBuf;

use systemds::api::{
    compile_with_meta, linreg_cg_args, CompileOptions, ExecBackend, Scenario, LINREG_CG,
};

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../tests/golden")
}

/// The scratch path embeds the process id (`scratch_space//_p1234//`);
/// normalise it so snapshots are stable across runs. Single rule shared
/// with the GDF plan diff (`util::fmt::normalize_scratch_pid`).
fn normalize(text: &str) -> String {
    systemds::util::fmt::normalize_scratch_pid(text)
}

fn explain_cg(backend: ExecBackend) -> String {
    let opts = CompileOptions { backend, ..Default::default() };
    let s = Scenario::xl1();
    let compiled = compile_with_meta(
        LINREG_CG,
        &linreg_cg_args(20),
        &s.meta(opts.cfg.blocksize),
        &opts,
    )
    .expect("LinReg CG compiles");
    compiled.explain_runtime()
}

fn check_golden(backend: ExecBackend) {
    let first = normalize(&explain_cg(backend));
    let second = normalize(&explain_cg(backend));
    assert_eq!(first, second, "{}: EXPLAIN must be deterministic", backend.name());

    let dir = golden_dir();
    let path = dir.join(format!("explain_linreg_cg_{}.txt", backend.name()));
    if !path.exists() {
        std::fs::create_dir_all(&dir).expect("create tests/golden");
        std::fs::write(&path, &first).expect("write golden snapshot");
        eprintln!("blessed new golden snapshot: {}", path.display());
        return;
    }
    let expected = std::fs::read_to_string(&path).expect("read golden snapshot");
    assert_eq!(
        first,
        expected,
        "{}: EXPLAIN diverged from {} — delete the snapshot and re-run to re-bless",
        backend.name(),
        path.display()
    );
}

#[test]
fn golden_explain_linreg_cg_cp() {
    check_golden(ExecBackend::Cp);
}

#[test]
fn golden_explain_linreg_cg_mr() {
    check_golden(ExecBackend::Mr);
}

#[test]
fn golden_explain_linreg_cg_spark() {
    check_golden(ExecBackend::Spark);
}

/// Structural pins that hold regardless of snapshot state: the three
/// backends produce visibly different plan families for the same script.
#[test]
fn backend_explains_are_structurally_distinct() {
    let cp = explain_cg(ExecBackend::Cp);
    let mr = explain_cg(ExecBackend::Mr);
    let spark = explain_cg(ExecBackend::Spark);
    assert!(!cp.contains("MR-Job[") && !cp.contains("SPARK-Job["), "{cp}");
    assert!(mr.contains("MR-Job["), "{mr}");
    assert!(!mr.contains("SPARK-Job["), "{mr}");
    assert!(spark.contains("SPARK-Job["), "{spark}");
    assert!(!spark.contains("MR-Job["), "{spark}");
    assert!(spark.contains("size CP/MR/SPARK ="), "{spark}");
    // the CG loop compiled with its literal trip count on every backend
    for text in [&cp, &mr, &spark] {
        assert!(text.contains("FOR ("), "{text}");
        assert!(text.contains("iterations=20"), "{text}");
    }
}
