//! Cross-module property tests (randomised via `systemds::util::prop` —
//! the offline stand-in for proptest): compiler/coordinator invariants
//! over random scenario sizes and cluster configurations.

use std::collections::HashSet;

use systemds::api::{compile_with_meta, CompileOptions, ExecBackend, Scenario, LINREG_DS};
use systemds::conf::{ClusterConfig, CostConstants, SystemConfig, MB};
use systemds::cost;
use systemds::ir::build::StaticMeta;
use systemds::matrix::{Format, MatrixCharacteristics};
use systemds::rtprog::{Instr, RtBlock, RtProgram};
use systemds::util::prop::forall;
use systemds::util::rng::Rng;

fn random_scenario(r: &mut Rng) -> (i64, i64, f64) {
    let rows = r.range_i64(1, 8) * 10i64.pow(r.range_i64(3, 8) as u32);
    let cols = r.range_i64(1, 40) * 100;
    let heap_mb = [256.0, 1024.0, 2048.0, 8192.0][r.below(4) as usize];
    (rows, cols, heap_mb)
}

fn compile_random(rows: i64, cols: i64, heap_mb: f64) -> (RtProgram, CompileOptions) {
    compile_random_backend(rows, cols, heap_mb, ExecBackend::Mr)
}

fn compile_random_backend(
    rows: i64,
    cols: i64,
    heap_mb: f64,
    backend: ExecBackend,
) -> (RtProgram, CompileOptions) {
    let mut cc = ClusterConfig::paper_cluster();
    cc.cp_heap_bytes = heap_mb * MB;
    cc.map_heap_bytes = heap_mb * MB;
    let opts = CompileOptions {
        cc: systemds::api::ClusterConfigOpt(cc),
        cfg: SystemConfig::default(),
        backend,
        ..Default::default()
    };
    let meta = StaticMeta::default()
        .with(
            "data/X",
            MatrixCharacteristics::dense(rows, cols, 1000),
            Format::BinaryBlock,
        )
        .with("data/y", MatrixCharacteristics::dense(rows, 1, 1000), Format::BinaryBlock);
    let c = compile_with_meta(LINREG_DS, &Scenario::xs().args(), &meta, &opts).unwrap();
    (c.runtime, opts)
}

fn all_insts(rt: &RtProgram) -> Vec<&Instr> {
    fn walk<'a>(blocks: &'a [RtBlock], out: &mut Vec<&'a Instr>) {
        for b in blocks {
            match b {
                RtBlock::Generic { insts, .. } => out.extend(insts.iter()),
                RtBlock::If { pred, then_blocks, else_blocks, .. } => {
                    out.extend(pred.insts.iter());
                    walk(then_blocks, out);
                    walk(else_blocks, out);
                }
                RtBlock::For { from, to, by, body, .. } => {
                    out.extend(from.insts.iter());
                    out.extend(to.insts.iter());
                    if let Some(by) = by {
                        out.extend(by.insts.iter());
                    }
                    walk(body, out);
                }
                RtBlock::While { pred, body, .. } => {
                    out.extend(pred.insts.iter());
                    walk(body, out);
                }
                RtBlock::FCall { .. } => {}
            }
        }
    }
    let mut v = Vec::new();
    walk(&rt.blocks, &mut v);
    v
}

/// Every MR-job input label is defined before the job (createvar/cpvar or
/// earlier job output), and every output has a prior createvar.
#[test]
fn prop_mr_job_labels_are_defined_before_use() {
    forall(
        40,
        0xA11CE,
        |r| {
            let (rows, cols, heap) = random_scenario(r);
            let backend = [ExecBackend::Mr, ExecBackend::Spark][r.below(2) as usize];
            (rows, cols, heap, backend)
        },
        |&(rows, cols, heap, backend)| {
            let (rt, _) = compile_random_backend(rows, cols, heap, backend);
            let mut defined: HashSet<String> = HashSet::new();
            for inst in all_insts(&rt) {
                match inst {
                    Instr::CreateVar { var, .. } => {
                        defined.insert(var.clone());
                    }
                    Instr::CpVar { dst, .. } => {
                        defined.insert(dst.clone());
                    }
                    Instr::AssignVar { var, .. } => {
                        defined.insert(var.clone());
                    }
                    Instr::Cp(c) => {
                        if let Some(n) = c.output.name() {
                            defined.insert(n.to_string());
                        }
                    }
                    Instr::MrJob(j) => {
                        for v in &j.inputs {
                            if !defined.contains(v) {
                                return Err(format!("job input '{v}' undefined"));
                            }
                        }
                        for v in &j.outputs {
                            if !defined.contains(v) {
                                return Err(format!("job output '{v}' lacks createvar"));
                            }
                        }
                    }
                    Instr::SparkJob(j) => {
                        for v in &j.inputs {
                            if !defined.contains(v) {
                                return Err(format!("spark input '{v}' undefined"));
                            }
                        }
                        for v in &j.outputs {
                            if !defined.contains(v) {
                                return Err(format!("spark output '{v}' lacks createvar"));
                            }
                        }
                    }
                    Instr::RmVar { .. } => {}
                }
            }
            Ok(())
        },
    );
}

/// Piggybacking invariants: byte indices are unique per job, instruction
/// inputs reference job inputs or earlier outputs, result indices exist.
#[test]
fn prop_piggyback_byte_indices_consistent() {
    forall(
        40,
        0xBEEF,
        |r| random_scenario(r),
        |&(rows, cols, heap)| {
            let (rt, _) = compile_random(rows, cols, heap);
            for inst in all_insts(&rt) {
                let Instr::MrJob(j) = inst else { continue };
                let mut produced: HashSet<usize> = (0..j.inputs.len()).collect();
                for mi in j.all_insts() {
                    for &i in &mi.inputs {
                        if !produced.contains(&i) {
                            return Err(format!("inst reads undefined index {i}"));
                        }
                    }
                    if !produced.insert(mi.output) {
                        return Err(format!("duplicate output index {}", mi.output));
                    }
                }
                for &ri in &j.result_indices {
                    if !produced.contains(&ri) {
                        return Err(format!("result index {ri} never produced"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Cost is monotone in data size (same script, same cluster).
#[test]
fn prop_cost_monotone_in_rows() {
    forall(
        25,
        0xC0DE,
        |r| {
            let cols = r.range_i64(1, 20) * 100;
            let rows = r.range_i64(1, 50) * 100_000;
            (rows, cols)
        },
        |&(rows, cols)| {
            let k = CostConstants::default();
            let (rt1, o1) = compile_random(rows, cols, 2048.0);
            let (rt2, o2) = compile_random(rows * 4, cols, 2048.0);
            let c1 = cost::cost_program(&rt1, &o1.cfg, &o1.cc.0, &k).total;
            let c2 = cost::cost_program(&rt2, &o2.cfg, &o2.cc.0, &k).total;
            // Strict monotonicity only holds while the plan is stable
            // (pure CP in both cases). Around the CP/MR boundary, the
            // greedy per-operator execution-type selection can produce
            // hybrid plans that, e.g., read X twice (once CP, once MR) —
            // the bigger input then compiles to a *better* all-MR plan.
            // This is faithful to SystemML (it is the motivation for the
            // global data-flow optimizer built on this cost model), so we
            // only demand a sanity bound across plan flips.
            if rt1.mr_job_count() == 0 && rt2.mr_job_count() == 0 {
                if c2 >= c1 * 0.99 {
                    Ok(())
                } else {
                    Err(format!("4x rows got cheaper: {c1} -> {c2}"))
                }
            } else if c2 >= c1 * 0.2 {
                Ok(())
            } else {
                Err(format!("plan flip but 5x cheaper: {c1} -> {c2}"))
            }
        },
    );
}

/// Costing is deterministic and strictly positive.
#[test]
fn prop_cost_deterministic_positive() {
    forall(
        30,
        0xD00D,
        |r| random_scenario(r),
        |&(rows, cols, heap)| {
            let k = CostConstants::default();
            let (rt, o) = compile_random(rows, cols, heap);
            let a = cost::cost_program(&rt, &o.cfg, &o.cc.0, &k).total;
            let b = cost::cost_program(&rt, &o.cfg, &o.cc.0, &k).total;
            if a != b {
                return Err(format!("nondeterministic: {a} vs {b}"));
            }
            if !(a.is_finite() && a > 0.0) {
                return Err(format!("non-positive cost {a}"));
            }
            Ok(())
        },
    );
}

/// More resources never add MR jobs (plan robustness under budget growth).
#[test]
fn prop_more_memory_never_more_jobs() {
    forall(
        25,
        0xFADE,
        |r| {
            let (rows, cols, _) = random_scenario(r);
            (rows, cols)
        },
        |&(rows, cols)| {
            let (small, _) = compile_random(rows, cols, 512.0);
            let (large, _) = compile_random(rows, cols, 8192.0);
            if large.mr_job_count() <= small.mr_job_count() {
                Ok(())
            } else {
                Err(format!(
                    "more heap, more jobs: {} -> {}",
                    small.mr_job_count(),
                    large.mr_job_count()
                ))
            }
        },
    );
}

/// For every backend: costs are finite, strictly positive and
/// deterministic on random scenario sizes and heap configurations.
#[test]
fn prop_backend_costs_finite_and_positive() {
    forall(
        30,
        0x5AA5,
        |r| {
            let (rows, cols, heap) = random_scenario(r);
            (rows, cols, heap)
        },
        |&(rows, cols, heap)| {
            let k = CostConstants::default();
            for backend in ExecBackend::all() {
                let (rt, o) = compile_random_backend(rows, cols, heap, backend);
                let a = cost::cost_program(&rt, &o.cfg, &o.cc.0, &k).total;
                let b = cost::cost_program(&rt, &o.cfg, &o.cc.0, &k).total;
                if !(a.is_finite() && a > 0.0) {
                    return Err(format!("{}: non-positive cost {a}", backend.name()));
                }
                if a != b {
                    return Err(format!("{}: nondeterministic {a} vs {b}", backend.name()));
                }
            }
            Ok(())
        },
    );
}

/// For every backend: cost is monotone non-decreasing in the matrix
/// dimensions at a fixed cluster configuration, as long as the plan
/// family is stable (equal distributed-job counts; around plan flips the
/// greedy per-operator selection can legitimately produce cheaper plans
/// for bigger inputs — see `prop_cost_monotone_in_rows`). The CP backend
/// never flips, so it is always monotone.
#[test]
fn prop_backend_cost_monotone_in_dims() {
    forall(
        20,
        0xB00C,
        |r| {
            let cols = r.range_i64(1, 20) * 100;
            let rows = r.range_i64(1, 50) * 100_000;
            (rows, cols)
        },
        |&(rows, cols)| {
            let k = CostConstants::default();
            for backend in ExecBackend::all() {
                let (rt1, o1) = compile_random_backend(rows, cols, 2048.0, backend);
                let (rt2, o2) = compile_random_backend(rows * 4, cols, 2048.0, backend);
                let c1 = cost::cost_program(&rt1, &o1.cfg, &o1.cc.0, &k).total;
                let c2 = cost::cost_program(&rt2, &o2.cfg, &o2.cc.0, &k).total;
                let stable = rt1.dist_job_count() == rt2.dist_job_count();
                if stable && c2 < c1 * 0.99 {
                    return Err(format!(
                        "{}: 4x rows got cheaper with a stable plan: {c1} -> {c2}",
                        backend.name()
                    ));
                }
                if !stable && c2 < c1 * 0.2 {
                    return Err(format!(
                        "{}: plan flip but 5x cheaper: {c1} -> {c2}",
                        backend.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Spark job latency is below MR job latency for the identical
/// single-job plan (the XL1-shaped wave, across data sizes that keep a
/// single fused GMR/Spark job).
#[test]
fn prop_spark_job_latency_below_mr() {
    for rows in [50_000_000i64, 100_000_000, 150_000_000] {
        let k = CostConstants::default();
        let (mr_rt, mo) = compile_random_backend(rows, 1_000, 2048.0, ExecBackend::Mr);
        let (sp_rt, so) = compile_random_backend(rows, 1_000, 2048.0, ExecBackend::Spark);
        assert_eq!(mr_rt.mr_job_count(), 1, "rows={rows}: single MR job expected");
        assert_eq!(sp_rt.spark_job_count(), 1, "rows={rows}: single Spark job expected");
        let mr_report = cost::cost_program(&mr_rt, &mo.cfg, &mo.cc.0, &k);
        let sp_report = cost::cost_program(&sp_rt, &so.cfg, &so.cc.0, &k);
        let mr_latency = find_mr_latency(&mr_report.nodes).expect("MR job breakdown");
        let sp_latency = find_spark_latency(&sp_report.nodes).expect("Spark job breakdown");
        assert!(
            sp_latency < mr_latency,
            "rows={rows}: spark latency {sp_latency} !< mr latency {mr_latency}"
        );
    }
}

fn find_mr_latency(nodes: &[cost::CostNode]) -> Option<f64> {
    for n in nodes {
        match n {
            cost::CostNode::Block { children, .. } => {
                if let Some(l) = find_mr_latency(children) {
                    return Some(l);
                }
            }
            cost::CostNode::Inst { cost, .. } => {
                if let Some(m) = &cost.mr {
                    return Some(m.latency);
                }
            }
        }
    }
    None
}

fn find_spark_latency(nodes: &[cost::CostNode]) -> Option<f64> {
    for n in nodes {
        match n {
            cost::CostNode::Block { children, .. } => {
                if let Some(l) = find_spark_latency(children) {
                    return Some(l);
                }
            }
            cost::CostNode::Inst { cost, .. } => {
                if let Some(s) = &cost.spark {
                    return Some(s.latency);
                }
            }
        }
    }
    None
}

/// rmvar never removes a variable still used afterwards in the block.
#[test]
fn prop_rmvar_after_last_use() {
    forall(
        30,
        0x5EED,
        |r| random_scenario(r),
        |&(rows, cols, heap)| {
            let (rt, _) = compile_random(rows, cols, heap);
            for b in &rt.blocks {
                let RtBlock::Generic { insts, .. } = b else { continue };
                let mut removed: HashSet<String> = HashSet::new();
                for inst in insts {
                    let uses: Vec<String> = match inst {
                        Instr::Cp(c) => c
                            .inputs
                            .iter()
                            .filter_map(|o| o.name().map(str::to_string))
                            .collect(),
                        Instr::MrJob(j) => j.inputs.clone(),
                        Instr::SparkJob(j) => j.inputs.clone(),
                        Instr::CpVar { src, .. } => vec![src.clone()],
                        _ => vec![],
                    };
                    for u in uses {
                        if removed.contains(&u) {
                            return Err(format!("use of '{u}' after rmvar"));
                        }
                    }
                    if let Instr::RmVar { vars } = inst {
                        removed.extend(vars.iter().cloned());
                    }
                }
            }
            Ok(())
        },
    );
}
