//! Integration tests for the incremental plan-costing engine: block-level
//! cost caching (`cost/cache.rs`) and the unified candidate evaluator
//! (`opt/evaluate.rs`).
//!
//! The load-bearing property: **cached and cache-disabled costing are
//! bitwise identical** — on every bundled script × backend × thread
//! count, through every optimizer entry point, under cache eviction
//! pressure, and under concurrent access to one shared cache.

use std::collections::HashMap;

use systemds::api::{
    compile_with_meta, linreg_cg_args, CompileOptions, DataScenario, ExecBackend, GdfSpec,
    ResourceGrid, Scenario, SweepSpec, LINREG_CG, LINREG_DS,
};
use systemds::conf::CostConstants;
use systemds::cost::{
    self,
    cache::{program_hashes, CostCache},
};
use systemds::matrix::Format;
use systemds::opt::gdf;
use systemds::opt::resource::optimize_grid;
use systemds::opt::sweep::{sweep, sweep_serial, NamedCluster};
use systemds::util::par;
use systemds::util::prop::forall;

/// Every bundled script on the XL1 data scenario.
fn bundled_scripts() -> Vec<(&'static str, &'static str, HashMap<usize, String>)> {
    vec![
        ("ds", LINREG_DS, Scenario::xs().args()),
        ("cg", LINREG_CG, linreg_cg_args(7)),
    ]
}

#[test]
fn cached_and_uncached_costing_bitwise_identical_on_every_script_and_backend() {
    let k = CostConstants::default();
    for (name, src, args) in bundled_scripts() {
        for scenario in [Scenario::xs(), Scenario::xl1()] {
            for backend in ExecBackend::all() {
                let opts = CompileOptions { backend, ..Default::default() };
                let c = compile_with_meta(src, &args, &scenario.meta(1000), &opts).unwrap();
                let tag = format!("{name}/{}/{}", scenario.name, backend.name());
                let full = cost::cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &k);
                // totals-only fast path
                let fast = cost::cost_total(&c.runtime, &opts.cfg, &opts.cc.0, &k);
                assert_eq!(full.total.to_bits(), fast.to_bits(), "{tag} totals-only");
                // cached paths, cold then warm
                let hashes = program_hashes(&c.runtime);
                let cache = CostCache::default();
                for pass in ["cold", "warm"] {
                    let cached = cost::cost_program_cached(
                        &c.runtime, &hashes, &opts.cfg, &opts.cc.0, &k, &cache,
                    );
                    assert_eq!(full.total.to_bits(), cached.total.to_bits(), "{tag} {pass}");
                    // annotated replay renders the identical costed EXPLAIN
                    assert_eq!(
                        cost::explain_costed(&full),
                        cost::explain_costed(&cached),
                        "{tag} {pass} explain"
                    );
                    let total_cached = cost::cost_total_cached(
                        &c.runtime, &hashes, &opts.cfg, &opts.cc.0, &k, &cache,
                    );
                    assert_eq!(full.total.to_bits(), total_cached.to_bits(), "{tag} {pass}");
                }
                assert!(cache.stats().hits > 0, "{tag}: warm pass must hit");
            }
        }
    }
}

/// Eviction pressure must degrade hit rate, never results: a cache far
/// too small for the program still replays bitwise-identical totals.
#[test]
fn tiny_cache_under_eviction_pressure_stays_exact() {
    let k = CostConstants::default();
    let s = Scenario::xl1();
    let opts = CompileOptions::default();
    let c =
        compile_with_meta(LINREG_CG, &linreg_cg_args(7), &s.meta(1000), &opts).unwrap();
    let reference = cost::cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &k).total;
    let hashes = program_hashes(&c.runtime);
    let cache = CostCache::new(2); // a couple of entries for a many-block walk
    for _ in 0..3 {
        let total =
            cost::cost_total_cached(&c.runtime, &hashes, &opts.cfg, &opts.cc.0, &k, &cache);
        assert_eq!(reference.to_bits(), total.to_bits());
    }
    let stats = cache.stats();
    assert!(stats.evictions > 0, "capacity 2 must evict: {stats:?}");
    assert!(stats.entries <= stats.capacity, "{stats:?}");
}

/// Concurrent costing through one shared cache: 16 workers costing a mix
/// of programs race on inserts and hits; every result must equal the
/// uncached reference bit for bit.
#[test]
fn concurrent_costing_through_shared_cache_is_exact() {
    let k = CostConstants::default();
    let opts = CompileOptions::default();
    let programs: Vec<_> = [Scenario::xs(), Scenario::xl1(), Scenario::xl2()]
        .into_iter()
        .map(|s| {
            let c = compile_with_meta(LINREG_CG, &linreg_cg_args(7), &s.meta(1000), &opts)
                .unwrap();
            let reference = cost::cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &k).total;
            let hashes = program_hashes(&c.runtime);
            (c, hashes, reference)
        })
        .collect();
    let cache = CostCache::default();
    let tasks: Vec<usize> = (0..48).map(|i| i % programs.len()).collect();
    let totals = par::par_map(&tasks, 16, |_, &p| {
        let (c, hashes, _) = &programs[p];
        cost::cost_total_cached(&c.runtime, hashes, &opts.cfg, &opts.cc.0, &k, &cache)
    });
    for (i, total) in totals.iter().enumerate() {
        let reference = programs[tasks[i]].2;
        assert_eq!(reference.to_bits(), total.to_bits(), "task {i}");
    }
    assert!(cache.stats().hits > 0);
}

/// Property: cached totals equal uncached totals bitwise across random
/// data sizes and backends.
#[test]
fn prop_cached_total_matches_uncached_on_random_scenarios() {
    forall(
        12,
        0xCAC4E,
        |r| {
            let rows = r.range_i64(1, 60) * 100_000;
            let cols = r.range_i64(1, 12) * 100;
            let backend = ExecBackend::all()[r.below(3) as usize];
            (rows, cols, backend)
        },
        |&(rows, cols, backend)| {
            let k = CostConstants::default();
            let opts = CompileOptions { backend, ..Default::default() };
            let scenario = DataScenario::linreg("R", rows, cols);
            let c = compile_with_meta(
                LINREG_DS,
                &Scenario::xs().args(),
                &scenario.meta(1000),
                &opts,
            )?;
            let reference = cost::cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &k).total;
            let hashes = program_hashes(&c.runtime);
            let cache = CostCache::default();
            for pass in 0..2 {
                let total = cost::cost_total_cached(
                    &c.runtime, &hashes, &opts.cfg, &opts.cc.0, &k, &cache,
                );
                if reference.to_bits() != total.to_bits() {
                    return Err(format!(
                        "{rows}x{cols} {} pass {pass}: {reference} != {total}",
                        backend.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The CG backend grid used by the sweep equality tests.
fn cg_sweep(threads: usize, cost_cache: bool) -> SweepSpec {
    let mut spec = SweepSpec::linreg_cg(10);
    spec.clusters = vec![NamedCluster::new(
        "paper-2048MB",
        systemds::conf::ClusterConfig::paper_cluster(),
    )];
    spec.scenarios = vec![
        DataScenario::linreg("XS", 10_000, 1_000),
        DataScenario::linreg("XL1", 100_000_000, 1_000),
    ];
    spec.backends = ExecBackend::all().to_vec();
    spec.threads = threads;
    spec.cost_cache = cost_cache;
    spec
}

#[test]
fn sweep_identical_with_cache_on_off_and_serial_across_thread_counts() {
    let reference = sweep_serial(&cg_sweep(1, true)).unwrap();
    for threads in [1, 4] {
        for cost_cache in [true, false] {
            let r = sweep(&cg_sweep(threads, cost_cache)).unwrap();
            assert_eq!(r.table(), reference.table(), "t={threads} cache={cost_cache}");
            for (a, b) in r.cells.iter().zip(&reference.cells) {
                assert_eq!(
                    a.cost_secs.to_bits(),
                    b.cost_secs.to_bits(),
                    "t={threads} cache={cost_cache} {}/{}",
                    a.scenario,
                    a.backend
                );
            }
        }
    }
}

#[test]
fn resource_grid_identical_with_cache_on_off() {
    let mk = |cost_cache: bool| {
        let s = Scenario::xl1();
        let mut g =
            ResourceGrid::new(LINREG_CG, linreg_cg_args(10), DataScenario::from(&s));
        g.threads = 4;
        g.cost_cache = cost_cache;
        g
    };
    let with = optimize_grid(&mk(true)).unwrap();
    let without = optimize_grid(&mk(false)).unwrap();
    assert_eq!(with.frontier_table(), without.frontier_table());
    assert_eq!(with.best, without.best);
    for (a, b) in with.points.iter().zip(&without.points) {
        match (a.cost_secs, b.cost_secs) {
            (Some(x), Some(y)) => assert_eq!(x.to_bits(), y.to_bits(), "{}", a.label()),
            (None, None) => {}
            _ => panic!("pruning diverged with the cache for {}", a.label()),
        }
    }
}

/// The GDF duplicate-skip satellite: partition-axis variants whose
/// backend assignment removes every MR job compile to identical plans
/// with identical observable knobs — they must be skipped, reported in
/// the decision trace, and cost bitwise the same as their twin.
#[test]
fn gdf_skips_duplicate_candidates_and_reports_them() {
    let s = Scenario::xl1();
    let mut spec = GdfSpec::new(LINREG_CG, linreg_cg_args(5), DataScenario::from(&s));
    spec.blocksizes = vec![1000];
    spec.formats = vec![Format::BinaryBlock];
    spec.partitions_mb = vec![8.0, 32.0];
    spec.threads = 2;
    let r = gdf::optimize(&spec).unwrap();
    assert!(
        r.skipped_duplicates > 0,
        "partition axis must produce MR-free duplicate plans: {:#?}",
        r.candidates.iter().map(|c| c.label()).collect::<Vec<_>>()
    );
    assert!(
        r.decision_table().contains("duplicate candidates skipped"),
        "{}",
        r.decision_table()
    );
    // every skipped candidate has an earlier twin (same bs/fmt/groups,
    // different partition) with the bitwise-identical cost
    for (i, c) in r.candidates.iter().enumerate() {
        if !c.cost_reused {
            continue;
        }
        let twin = r.candidates[..i].iter().find(|d| {
            d.blocksize == c.blocksize && d.format == c.format && d.groups == c.groups
        });
        let twin = twin.unwrap_or_else(|| panic!("no twin for {}", c.label()));
        assert_eq!(twin.cost_secs.to_bits(), c.cost_secs.to_bits(), "{}", c.label());
        assert_eq!(c.mr_jobs, 0, "only MR-free plans can ignore the partition knob");
    }
}

#[test]
fn gdf_identical_with_cache_on_off() {
    let s = Scenario::xl1();
    let mk = |cost_cache: bool| {
        let mut spec = GdfSpec::linreg_cg(DataScenario::from(&s), 10);
        spec.blocksizes = vec![1000, 2000];
        spec.formats = vec![Format::BinaryBlock];
        spec.partitions_mb = vec![32.0];
        spec.threads = 4;
        spec.cost_cache = cost_cache;
        spec
    };
    let with = gdf::optimize(&mk(true)).unwrap();
    let without = gdf::optimize(&mk(false)).unwrap();
    assert_eq!(with.best, without.best);
    assert_eq!(with.candidates.len(), without.candidates.len());
    for (a, b) in with.candidates.iter().zip(&without.candidates) {
        assert_eq!(a.cost_secs.to_bits(), b.cost_secs.to_bits(), "{}", a.label());
    }
    assert_eq!(with.explain_diff(), without.explain_diff());
    // the cached run actually exercised the cache
    assert!(with.cache_hits + with.cache_misses > 0);
    assert_eq!(without.cache_hits + without.cache_misses, 0);
}
