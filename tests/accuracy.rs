//! Tier-1 accuracy suite (promoted from the old `cost_accuracy` example,
//! paper §3.4): every bundled calibration scenario is compiled, costed
//! with the white-box model and *actually executed* on the in-process
//! runtime, and the joined per-block records must be complete, correctly
//! keyed, and within a generous bound of the measured proxy time; the
//! feedback loop itself must never make the geo-mean Q-error worse.
//!
//! Bounds on wall-clock comparisons are deliberately loose (the defaults
//! model the paper's Hadoop cluster, not this machine — that gap is
//! exactly what `repro calibrate` closes); the structural assertions
//! (record completeness, hash keying, rerun stability) are exact.

use systemds::api::{compile, ClusterConfigOpt, CompileOptions};
use systemds::conf::CostConstants;
use systemds::cost::cache::program_hashes;
use systemds::cp::interp::Executor;
use systemds::feedback::runner::cluster_for;
use systemds::feedback::{
    bundled_cases, calibrate, measure_case, qerror, CalibrateOptions, CalibrationCase,
    MeasureMode,
};
use systemds::matrix::{io, ops, DenseMatrix};
use systemds::mr;
use systemds::rtprog::{Instr, RtBlock, RtProgram};

/// Per-test scratch directory (tests run in parallel in one process).
fn scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("sysds_accuracy_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Generate data for `case`, write it under `dir`, compile the case's
/// script against its bundled cluster and return the plan plus args.
fn compile_case(
    case: &CalibrationCase,
    dir: &std::path::Path,
    threads: usize,
) -> (RtProgram, CompileOptions) {
    let x = DenseMatrix::rand(case.rows, case.cols, -1.0, 1.0, 1.0, 42);
    let beta = DenseMatrix::rand(case.cols, 1, -0.5, 0.5, 1.0, 43);
    let y = ops::matmult(&x, &beta, threads);
    let xp = dir.join("X").to_string_lossy().to_string();
    let yp = dir.join("y").to_string_lossy().to_string();
    io::write_binary_block(&xp, &x, 1000).unwrap();
    io::write_binary_block(&yp, &y, 1000).unwrap();
    let mut args = std::collections::HashMap::new();
    args.insert(1, xp);
    args.insert(2, yp);
    args.insert(3, "0".to_string());
    args.insert(4, dir.join("out").to_string_lossy().to_string());
    let cc = cluster_for(threads, case);
    let opts = CompileOptions { cc: ClusterConfigOpt(cc), ..Default::default() };
    let compiled = compile(case.script, &args, &opts).expect("compile bundled case");
    (compiled.runtime, opts)
}

#[test]
fn executed_records_are_complete_and_keyed_by_block_hashes() {
    let dir = scratch("records");
    let k = CostConstants::default();
    for case in bundled_cases(true) {
        let m = measure_case(&case, MeasureMode::Execute, 2, &k, 42, &dir, None)
            .expect("measure bundled case");
        // one record per costed top-level block, in program order
        assert_eq!(m.records.len(), m.rt.blocks.len(), "{}", case.name);
        // keyed by the structural block hashes the cost cache uses
        let roots = m.hashes.block_roots();
        assert_eq!(m.records.len(), roots.len(), "{}", case.name);
        for (r, root) in m.records.iter().zip(roots) {
            assert_eq!(r.hash, root, "{}: record key != block hash", case.name);
            assert!(r.predicted_secs.is_finite(), "{}", case.name);
            assert!(r.measured_secs.is_finite() && r.measured_secs >= 0.0, "{}", case.name);
            // the breakdown partitions the prediction
            assert!(
                (r.breakdown.total() - r.predicted_secs).abs()
                    <= 1e-9 * r.predicted_secs.max(1.0),
                "{}: breakdown does not sum to the prediction",
                case.name
            );
        }
        let stats = m.stats.expect("execute mode captures stats");
        assert!(stats.cp_insts > 0, "{}", case.name);
    }
}

#[test]
fn predictions_within_generous_bound_of_measured_proxy() {
    let dir = scratch("bound");
    let k = CostConstants::default();
    for case in bundled_cases(true) {
        let m = measure_case(&case, MeasureMode::Execute, 2, &k, 42, &dir, None).unwrap();
        let pred: f64 = m.records.iter().map(|r| r.predicted_secs).sum();
        let meas: f64 = m.records.iter().map(|r| r.measured_secs).sum();
        assert!(meas > 0.0, "{}: nothing measured", case.name);
        let q = qerror(pred, meas);
        // CP-resident cases: the Hadoop-calibrated defaults and this
        // machine disagree by a constant factor, not orders of magnitude.
        // The MR-forced case pays 20 s of modelled job latency per job
        // against a millisecond in-process simulator, so its bound is the
        // sanity kind only.
        let bound = if case.heap_mb >= 1.0 { 1e3 } else { 1e7 };
        assert!(
            q.is_finite() && q <= bound,
            "{}: q-error {q:.1} exceeds {bound} (pred {pred:.4}s, meas {meas:.4}s)",
            case.name
        );
    }
}

#[test]
fn quick_execute_calibration_never_increases_geo_mean_qerror() {
    let opts = CalibrateOptions {
        quick: true,
        threads: 2,
        scratch: Some(scratch("calib")),
        ..Default::default()
    };
    let report = calibrate(&opts).expect("quick execute calibration");
    assert_eq!(report.cases, bundled_cases(true).len());
    assert!(report.executed);
    assert!(report.before.n > 0);
    assert_eq!(report.before.n, report.after.n);
    // the outer safeguard reverts to identity rather than regress
    assert!(
        report.after.geo_mean <= report.before.geo_mean,
        "calibration regressed geo-mean q-error: {} -> {}",
        report.before.geo_mean,
        report.after.geo_mean
    );
    // calibrated constants are always usable
    report.calibrated.validate().expect("calibrated constants validate");
}

#[test]
fn exec_stats_and_block_timings_stable_across_reruns() {
    let threads = 2;
    let case = bundled_cases(true)
        .into_iter()
        .find(|c| c.heap_mb < 1.0)
        .expect("bundled MR-forced case");
    let dir = scratch("stats");
    let (rt, opts) = compile_case(&case, &dir, threads);

    let run = |i: usize| {
        let mut exec = Executor::new(&opts.cfg, &opts.cc.0, None, dir.join(format!("s{i}")));
        exec.run_instrumented(&rt).expect("execute bundled case")
    };
    let (s1, t1) = run(1);
    let (s2, t2) = run(2);
    // per-block timing records are complete and aligned
    assert_eq!(t1.len(), rt.blocks.len());
    assert_eq!(t2.len(), rt.blocks.len());
    assert_eq!(
        t1.len(),
        program_hashes(&rt).block_roots().len(),
        "timings align with the structural hash keys"
    );
    // everything except wall-clock is deterministic across reruns
    assert_eq!(s1.cp_insts, s2.cp_insts);
    assert_eq!(s1.mr_jobs, s2.mr_jobs);
    assert!(s1.mr_jobs > 0, "tiny heap must force MR jobs");
    assert_eq!(s1.map_tasks, s2.map_tasks);
    assert_eq!(s1.shuffle_bytes.to_bits(), s2.shuffle_bytes.to_bits());
    assert_eq!(s1.hdfs_read_bytes.to_bits(), s2.hdfs_read_bytes.to_bits());
    assert_eq!(s1.hdfs_write_bytes.to_bits(), s2.hdfs_write_bytes.to_bits());
    // instrumented and plain runs agree on the work done
    let mut exec = Executor::new(&opts.cfg, &opts.cc.0, None, dir.join("s3"));
    let s3 = exec.run(&rt).expect("plain run");
    assert_eq!(s1.cp_insts, s3.cp_insts);
    assert_eq!(s1.mr_jobs, s3.mr_jobs);
    assert_eq!(s1.map_tasks, s3.map_tasks);
}

#[test]
fn mr_simulate_is_deterministic_given_the_same_inputs() {
    let threads = 2;
    let case = bundled_cases(true)
        .into_iter()
        .find(|c| c.heap_mb < 1.0)
        .expect("bundled MR-forced case");
    let dir = scratch("simulate");
    let (rt, opts) = compile_case(&case, &dir, threads);

    // drive the interpreter up to the first MR job, then invoke the
    // cluster simulator directly
    let simulate_first = |i: usize| {
        let mut exec = Executor::new(&opts.cfg, &opts.cc.0, None, dir.join(format!("m{i}")));
        for block in &rt.blocks {
            if let RtBlock::Generic { insts, .. } = block {
                for inst in insts {
                    if let Instr::MrJob(job) = inst {
                        return mr::simulate(job, &mut exec).expect("simulate MR job");
                    }
                    exec.exec_inst(inst).expect("execute prefix instruction");
                }
            }
        }
        panic!("{}: no MR job in the compiled plan", case.name);
    };
    let r1 = simulate_first(1);
    let r2 = simulate_first(2);
    assert!(r1.map_tasks >= 2, "2 MB HDFS blocks must split the input");
    assert!(r1.input_bytes > 0.0);
    assert_eq!(r1.map_tasks, r2.map_tasks);
    assert_eq!(r1.reduce_groups, r2.reduce_groups);
    assert_eq!(r1.shuffle_bytes.to_bits(), r2.shuffle_bytes.to_bits());
    assert_eq!(r1.input_bytes.to_bits(), r2.input_bytes.to_bits());
}
