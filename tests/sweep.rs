//! Integration tests for the parallel scenario-sweep costing engine
//! (`opt::sweep` / `api::sweep`): determinism, plan-memoization hit
//! counts, parallel-vs-serial agreement, and the size-monotonicity
//! property (a strictly larger scenario never costs less while the plan
//! shape is stable).

use systemds::api::{self, DataScenario, ExecBackend, NamedCluster, SweepSpec};
use systemds::conf::{ClusterConfig, MB};
use systemds::opt::sweep::{heap_clock_clusters, sweep, sweep_serial};
use systemds::util::prop::forall;

/// A compact grid with clock-only cluster variants (plan sharing) and
/// heap variants (plan flips): 3 scenarios × 4 clusters = 12 cells.
fn grid() -> SweepSpec {
    let mut spec = SweepSpec::linreg_default();
    spec.scenarios = vec![
        DataScenario::linreg("XS", 10_000, 1_000),
        DataScenario::linreg("M", 1_000_000, 500),
        DataScenario::linreg("XL1", 100_000_000, 1_000),
    ];
    spec.clusters = heap_clock_clusters(&[512.0, 2048.0]);
    spec.threads = 4;
    spec
}

#[test]
fn same_grid_gives_identical_ranked_output() {
    let spec = grid();
    let a = sweep(&spec).unwrap();
    let b = sweep(&spec).unwrap();
    assert_eq!(a.table(), b.table(), "ranked table must be deterministic");
    assert_eq!(a.ranking, b.ranking);
    for (ca, cb) in a.cells.iter().zip(&b.cells) {
        assert_eq!(ca.cost_secs.to_bits(), cb.cost_secs.to_bits(), "{} {}", ca.scenario, ca.cluster);
        assert_eq!(ca.plan_reused, cb.plan_reused);
    }
}

#[test]
fn parallel_and_serial_sweeps_agree_exactly() {
    let spec = grid();
    let par = sweep(&spec).unwrap();
    let ser = sweep_serial(&spec).unwrap();
    assert_eq!(par.table(), ser.table());
    assert_eq!(par.distinct_plans, ser.distinct_plans);
    assert_eq!(par.memo_hits, ser.memo_hits);
    for (cp, cs) in par.cells.iter().zip(&ser.cells) {
        assert_eq!(cp.cost_secs.to_bits(), cs.cost_secs.to_bits());
        assert_eq!(cp.mr_jobs, cs.mr_jobs);
        assert_eq!(cp.cp_insts, cs.cp_insts);
    }
}

#[test]
fn memoization_hit_counts_match_clock_variants() {
    let spec = grid();
    let r = sweep(&spec).unwrap();
    assert_eq!(r.cells.len(), 12);
    // fast-* clusters differ from their paper-* siblings only in clock
    // rate, which never changes plan shape: exactly half the grid reuses.
    assert_eq!(r.distinct_plans, 6, "3 scenarios x 2 heap sizes");
    assert_eq!(r.memo_hits, 6);
    let reused = r.cells.iter().filter(|c| c.plan_reused).count();
    assert_eq!(reused, r.memo_hits);
    // reused cells must reference a signature some fresh cell compiled
    for c in r.cells.iter().filter(|c| c.plan_reused) {
        assert!(
            r.cells.iter().any(|o| !o.plan_reused && o.plan_sig == c.plan_sig),
            "dangling memo reference for {} / {}",
            c.scenario,
            c.cluster
        );
    }
}

#[test]
fn api_sweep_wrapper_matches_engine() {
    let spec = grid();
    let via_api = api::sweep(&spec).unwrap();
    let direct = sweep(&spec).unwrap();
    assert_eq!(via_api.table(), direct.table());
}

/// Adding a strictly larger scenario never lowers its estimated cost.
/// Constrained to the CP-stable regime (inputs comfortably inside the
/// memory budget) where the plan shape cannot flip — around the CP/MR
/// boundary greedy per-operator selection can legitimately produce a
/// cheaper all-MR plan for bigger data (see `prop_cost_monotone_in_rows`
/// in tests/properties.rs).
#[test]
fn prop_larger_scenario_never_costs_less() {
    forall(
        20,
        0x5EEB,
        |r| {
            let cols = r.range_i64(1, 5) * 100; // 100..500
            let max_rows = 10_000_000 / cols; // keep <= 1e7 cells small side
            let rows = r.range_i64(1_000, max_rows.max(1_001));
            (rows, cols)
        },
        |&(rows, cols)| {
            let mut spec = SweepSpec::linreg_default();
            let mut cc = ClusterConfig::paper_cluster();
            cc.cp_heap_bytes = 2048.0 * MB;
            cc.map_heap_bytes = 2048.0 * MB;
            spec.clusters = vec![NamedCluster::new("paper-2048MB", cc)];
            spec.scenarios = vec![
                DataScenario::linreg("small", rows, cols),
                DataScenario::linreg("large", rows * 2, cols),
            ];
            spec.threads = 2;
            let r = sweep(&spec).map_err(|e| e.to_string())?;
            let cost = |name: &str| {
                r.cells.iter().find(|c| c.scenario == name).unwrap().cost_secs
            };
            let (small, large) = (cost("small"), cost("large"));
            if large + 1e-12 >= small {
                Ok(())
            } else {
                Err(format!(
                    "{rows}x{cols}: doubling rows lowered cost {small} -> {large}"
                ))
            }
        },
    );
}

/// The backend-axis grid for the iterative LinReg CG script: one cluster,
/// all three backends, a small and a paper-scale scenario.
fn backend_grid() -> SweepSpec {
    let mut spec = SweepSpec::linreg_cg(20);
    spec.clusters = vec![NamedCluster::new("paper-2048MB", ClusterConfig::paper_cluster())];
    spec.scenarios = vec![
        DataScenario::linreg("XS", 10_000, 1_000),
        DataScenario::linreg("XL1", 100_000_000, 1_000),
    ];
    spec.backends = ExecBackend::all().to_vec();
    spec.threads = 4;
    spec
}

fn cell_cost(r: &api::SweepReport, scenario: &str, backend: &str) -> f64 {
    r.cells
        .iter()
        .find(|c| c.scenario == scenario && c.backend == backend)
        .unwrap_or_else(|| panic!("missing cell {scenario}/{backend}"))
        .cost_secs
}

/// Acceptance regime 1: Spark beats MR on multi-iteration loops — every
/// CG iteration submits distributed jobs, and the 20 s MR job latency
/// dominates where Spark's ~1 s submission does not (Kaoudi et al. 2017).
#[test]
fn spark_beats_mr_on_iterative_loops() {
    let r = sweep(&backend_grid()).unwrap();
    let spark = cell_cost(&r, "XL1", "spark");
    let mr = cell_cost(&r, "XL1", "mr");
    assert!(
        spark < mr,
        "latency-dominated loop: spark {spark} must beat mr {mr}"
    );
    // and the margin is structural, not noise: MR pays at least one
    // 20 s job submission per iteration that Spark does not
    assert!(mr - spark > 100.0, "spark {spark} vs mr {mr}");
}

/// Acceptance regime 2: CP wins when the data fits the heap. The 80 MB
/// XS scenario compiles to the identical pure-CP plan on all three
/// backends (the hybrid backends agree nothing needs distribution), and
/// the deterministic tie-break ranks the single-node backend first.
#[test]
fn cp_wins_when_data_fits_heap() {
    let r = sweep(&backend_grid()).unwrap();
    let cp = cell_cost(&r, "XS", "cp");
    assert!(cp <= cell_cost(&r, "XS", "mr"));
    assert!(cp <= cell_cost(&r, "XS", "spark"));
    let first = r.ranked().next().unwrap();
    assert_eq!(first.scenario, "XS");
    assert_eq!(first.backend, "cp", "single-node backend ranks first on ties");
}

/// Acceptance regime 3: single-node execution loses badly once the data
/// outgrows the heap — the distributed backends win XL1 outright.
#[test]
fn cp_loses_when_data_outgrows_heap() {
    let r = sweep(&backend_grid()).unwrap();
    let cp = cell_cost(&r, "XL1", "cp");
    assert!(cell_cost(&r, "XL1", "spark") < cp);
    assert!(cell_cost(&r, "XL1", "mr") < cp);
}

/// Sweep determinism with the backend axis enabled: 1 worker thread and
/// N worker threads produce bit-identical ranked tables.
#[test]
fn backend_sweep_identical_across_thread_counts() {
    let mut one = backend_grid();
    one.threads = 1;
    let mut many = backend_grid();
    many.threads = 8;
    let a = sweep(&one).unwrap();
    let b = sweep(&many).unwrap();
    assert_eq!(a.table(), b.table(), "1 vs 8 threads must agree");
    assert_eq!(a.ranking, b.ranking);
    for (ca, cb) in a.cells.iter().zip(&b.cells) {
        assert_eq!(ca.cost_secs.to_bits(), cb.cost_secs.to_bits());
        assert_eq!(ca.backend, cb.backend);
        assert_eq!((ca.mr_jobs, ca.spark_jobs), (cb.mr_jobs, cb.spark_jobs));
    }
    // the serial reference agrees too
    let s = sweep_serial(&one).unwrap();
    assert_eq!(a.table(), s.table());
}

/// The ranked table carries the backend column and one row per cell.
#[test]
fn backend_table_shape() {
    let r = sweep(&backend_grid()).unwrap();
    assert_eq!(r.cells.len(), 6);
    let table = r.table();
    assert_eq!(table.lines().count(), 2 + r.cells.len(), "{table}");
    assert!(table.contains("backend"), "{table}");
    for b in ["cp", "mr", "spark"] {
        assert!(table.contains(b), "{table}");
    }
}

#[test]
fn ranked_order_puts_smaller_work_first_on_one_cluster() {
    let mut spec = SweepSpec::linreg_default();
    let mut cc = ClusterConfig::paper_cluster();
    cc.cp_heap_bytes = 2048.0 * MB;
    cc.map_heap_bytes = 2048.0 * MB;
    spec.clusters = vec![NamedCluster::new("paper", cc)];
    spec.scenarios = vec![
        DataScenario::linreg("s1", 10_000, 200),
        DataScenario::linreg("s2", 40_000, 200),
        DataScenario::linreg("s3", 160_000, 200),
    ];
    let r = sweep(&spec).unwrap();
    let order: Vec<&str> = r.ranked().map(|c| c.scenario.as_str()).collect();
    assert_eq!(order, vec!["s1", "s2", "s3"]);
}
