//! End-to-end integration: compile the paper's LinReg DS script from real
//! binary-block files, execute the generated hybrid plan (CP and
//! MR-simulator paths), and validate the numerics against the
//! normal-equations solution computed directly.

use std::collections::HashMap;
use std::sync::Arc;

use systemds::api::{compile, CompileOptions, LINREG_DS};
use systemds::conf::{ClusterConfig, MB};
use systemds::cp::interp::Executor;
use systemds::matrix::{io, ops, DenseMatrix};

fn workdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("sysds_e2e_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Generate data, write inputs, return ($N args, X, y).
fn setup(tag: &str, rows: usize, cols: usize) -> (HashMap<usize, String>, DenseMatrix, DenseMatrix) {
    let dir = workdir(tag);
    let x = DenseMatrix::rand(rows, cols, -1.0, 1.0, 1.0, 42);
    let beta_true = DenseMatrix::rand(cols, 1, -0.5, 0.5, 1.0, 43);
    let y = ops::matmult(&x, &beta_true, 4);
    let xp = dir.join("X").to_string_lossy().to_string();
    let yp = dir.join("y").to_string_lossy().to_string();
    io::write_binary_block(&xp, &x, 256).unwrap();
    io::write_binary_block(&yp, &y, 256).unwrap();
    let mut args = HashMap::new();
    args.insert(1, xp);
    args.insert(2, yp);
    args.insert(3, "0".to_string());
    args.insert(4, dir.join("beta").to_string_lossy().to_string());
    (args, x, y)
}

/// Closed-form reference: beta = solve(X'X + 0.001 I, X'y).
fn reference_beta(x: &DenseMatrix, y: &DenseMatrix) -> DenseMatrix {
    let mut a = ops::tsmm_left(x, 4);
    for i in 0..a.rows {
        a.values[i * a.cols + i] += 0.001;
    }
    let b = ops::matmult(&ops::transpose(x), y, 4);
    ops::solve(&a, &b).unwrap()
}

fn run_and_check(opts: &CompileOptions, args: &HashMap<usize, String>, x: &DenseMatrix, y: &DenseMatrix, expect_mr: bool) {
    let compiled = compile(LINREG_DS, args, opts).expect("compiles");
    let (_, mr) = compiled.runtime.size();
    if expect_mr {
        assert!(mr > 0, "plan should contain MR jobs\n{}", compiled.explain_runtime());
    } else {
        assert_eq!(mr, 0, "plan should be pure CP\n{}", compiled.explain_runtime());
    }
    let scratch = workdir("scratch");
    let mut exec = Executor::new(&opts.cfg, &opts.cc.0, None, scratch);
    let stats = exec.run(&compiled.runtime).expect("executes");
    assert!(stats.cp_insts > 0);
    // read the persisted beta and compare with the closed-form solution
    let beta_path = args.get(&4).unwrap();
    let beta = io::read_matrix(beta_path).expect("beta written");
    let reference = reference_beta(x, y);
    assert!(
        beta.max_abs_diff(&reference) < 1e-6,
        "beta mismatch: {}",
        beta.max_abs_diff(&reference)
    );
}

#[test]
fn cp_plan_executes_and_matches_reference() {
    let (args, x, y) = setup("cp", 512, 64);
    let opts = CompileOptions {
        cc: systemds::api::ClusterConfigOpt(ClusterConfig::local(4, 2048.0 * MB)),
        ..Default::default()
    };
    run_and_check(&opts, &args, &x, &y, false);
}

#[test]
fn mr_plan_executes_and_matches_reference() {
    // A tiny memory budget forces the matmults onto the MR simulator.
    let (args, x, y) = setup("mr", 600, 48);
    let mut cc = ClusterConfig::local(4, 2048.0 * MB);
    cc.cp_heap_bytes = 0.5 * MB; // ~360KB budget: X (230KB)+t(X)+out > budget
    cc.hdfs_block_bytes = 64.0 * 1024.0;
    let mut opts = CompileOptions {
        cc: systemds::api::ClusterConfigOpt(cc),
        ..Default::default()
    };
    opts.cfg.blocksize = 64;
    run_and_check(&opts, &args, &x, &y, true);
}

#[test]
fn intercept_branch_executes() {
    let (mut args, x, y) = setup("icpt", 300, 20);
    args.insert(3, "1".to_string());
    let opts = CompileOptions {
        cc: systemds::api::ClusterConfigOpt(ClusterConfig::local(4, 2048.0 * MB)),
        ..Default::default()
    };
    let compiled = compile(LINREG_DS, &args, &opts).expect("compiles");
    let scratch = workdir("scratch_i");
    let mut exec = Executor::new(&opts.cfg, &opts.cc.0, None, scratch);
    exec.run(&compiled.runtime).expect("executes");
    let beta = io::read_matrix(args.get(&4).unwrap()).unwrap();
    assert_eq!(beta.rows, 21, "intercept column appended");
    // residual must be tiny (y was generated noise-free, intercept ~ 0)
    let xa = ops::cbind(&x, &DenseMatrix::filled(x.rows, 1, 1.0));
    let pred = ops::matmult(&xa, &beta, 4);
    let resid: f64 = pred
        .values
        .iter()
        .zip(&y.values)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    // λ-regularisation biases the 21 coefficients slightly; the residual
    // is small but not zero.
    assert!(resid < 1e-2, "residual {resid}");
}

#[test]
fn control_flow_script_executes() {
    let dir = workdir("ctrl");
    let x = DenseMatrix::rand(64, 8, 0.0, 1.0, 1.0, 7);
    let xp = dir.join("X").to_string_lossy().to_string();
    io::write_binary_block(&xp, &x, 64).unwrap();
    let out = dir.join("out").to_string_lossy().to_string();
    let src = r#"
X = read($1);
s = 0;
for (i in 1:5) { s = s + sum(X); }
acc = matrix(0, nrow(X), ncol(X));
while (as.scalar(acc[1,1]) == 999) { acc = acc; }
if (s > 0) { Z = X * 2; } else { Z = X; }
r = sum(Z) + s;
write(r, $4);
"#;
    // our subset has no indexing; replace the while with a scalar loop
    let src = src.replace(
        "while (as.scalar(acc[1,1]) == 999) { acc = acc; }",
        "k = 0; while (k < 3) { k = k + 1; }",
    );
    let mut args = HashMap::new();
    args.insert(1, xp);
    args.insert(4, out.clone());
    let opts = CompileOptions {
        cc: systemds::api::ClusterConfigOpt(ClusterConfig::local(2, 1024.0 * MB)),
        ..Default::default()
    };
    let compiled = compile(&src, &args, &opts).expect("compiles");
    let scratch = workdir("ctrl_scratch");
    let mut exec = Executor::new(&opts.cfg, &opts.cc.0, None, scratch);
    exec.run(&compiled.runtime).expect("executes");
    let r = io::read_matrix(&out).unwrap();
    let s = ops::sum(&x);
    let expect = 5.0 * s + 2.0 * s;
    assert!((r.get(0, 0) - expect).abs() < 1e-9, "{} vs {expect}", r.get(0, 0));
}

#[test]
fn function_call_executes() {
    let dir = workdir("func");
    let out = dir.join("out").to_string_lossy().to_string();
    let src = r#"
scale = function(double a, double s) return (double b) { b = a * s; }
x = 7;
y = scale(x, 3);
write(y, $4);
"#;
    let mut args = HashMap::new();
    args.insert(4, out.clone());
    let opts = CompileOptions::default();
    let compiled = compile(src, &args, &opts).expect("compiles");
    let mut exec = Executor::new(&opts.cfg, &opts.cc.0, None, workdir("func_scratch"));
    exec.run(&compiled.runtime).expect("executes");
    let r = io::read_matrix(&out).unwrap();
    assert_eq!(r.get(0, 0), 21.0);
}

#[test]
fn buffer_pool_eviction_under_pressure_still_correct() {
    let (args, x, y) = setup("pool", 400, 32);
    let mut cc = ClusterConfig::local(2, 2048.0 * MB);
    // pool capacity = 0.7 * heap; make it ~ 200KB so X (102KB) + t(X) evicts
    cc.cp_heap_bytes = 150.0 * 1024.0;
    // but keep the optimizer thinking everything fits (force CP) by
    // costing against a generous budget: compile with a big heap...
    let big = ClusterConfig::local(2, 2048.0 * MB);
    let opts = CompileOptions {
        cc: systemds::api::ClusterConfigOpt(big),
        ..Default::default()
    };
    let compiled = compile(LINREG_DS, &args, &opts).expect("compiles");
    let mut exec = Executor::new(&opts.cfg, &cc, None, workdir("pool_scratch"));
    let stats = exec.run(&compiled.runtime).expect("executes under pressure");
    assert!(stats.pool_evictions > 0, "expected evictions, got {stats:?}");
    let beta = io::read_matrix(args.get(&4).unwrap()).unwrap();
    let reference = reference_beta(&x, &y);
    assert!(beta.max_abs_diff(&reference) < 1e-6);
}

#[test]
fn exec_stats_accumulate() {
    let (args, _, _) = setup("stats", 256, 16);
    let opts = CompileOptions {
        cc: systemds::api::ClusterConfigOpt(ClusterConfig::local(2, 1024.0 * MB)),
        ..Default::default()
    };
    let compiled = compile(LINREG_DS, &args, &opts).unwrap();
    let mut exec = Executor::new(&opts.cfg, &opts.cc.0, None, workdir("stats_scratch"));
    let stats = exec.run(&compiled.runtime).unwrap();
    assert!(stats.cp_insts >= 9);
    assert!(stats.elapsed_secs > 0.0);
    assert!(stats.hdfs_write_bytes > 0.0);
    let _ = Arc::new(0); // keep Arc import used
}
