//! Integration tests for the grid resource optimizer
//! (`opt::resource::optimize_grid` / `api::optimize_resources`): axis
//! coverage with memoization (the acceptance criterion: strictly fewer
//! compile invocations than grid points), Pareto-frontier properties,
//! pruning soundness (identical argmin/frontier with pruning on and
//! off), determinism across thread counts, NaN-safe rejection of
//! degenerate configurations, and the persistent-read-floor lower-bound
//! property across random scenarios, clusters and backends.

use systemds::api::{
    compile_with_meta, linreg_cg_args, optimize_resources, ClusterConfigOpt, CompileOptions,
    DataScenario, ExecBackend, ResourceGrid, Scenario, LINREG_CG, LINREG_DS,
};
use systemds::conf::{ClusterConfig, CostConstants, SystemConfig};
use systemds::cost;
use systemds::matrix::{Format, MatrixCharacteristics};
use systemds::opt::resource::{optimize_grid, GridPoint};
use systemds::util::prop::forall;

/// The LinReg CG grid of the acceptance criterion: default joint axes
/// (3 heaps × 2 executor memories × 2 node counts × 2 `k_local` × 3
/// backends) on the given data scenario.
fn cg_grid(s: &Scenario, iters: usize) -> ResourceGrid {
    let mut g = ResourceGrid::new(LINREG_CG, linreg_cg_args(iters), DataScenario::from(s));
    g.threads = 4;
    g
}

#[test]
fn cg_grid_explores_three_plus_axes_and_memoizes() {
    let g = cg_grid(&Scenario::xl1(), 20);
    // >= 3 explored axes: heap, parallelism (nodes and k_local), backend
    assert!(g.heaps_mb.len() >= 2, "heap axis");
    assert!(g.nodes.len() >= 2 && g.k_local.len() >= 2, "parallelism axes");
    assert!(g.backends.len() >= 3, "backend axis");
    let r = optimize_grid(&g).unwrap();
    assert_eq!(r.points.len(), g.point_count());
    // the memoized parallel grid costs strictly fewer compile+cost
    // invocations than grid-size, with a positive memo hit-rate
    assert!(
        r.distinct_plans < g.point_count(),
        "{} compiles for {} points",
        r.distinct_plans,
        g.point_count()
    );
    assert!(r.memo_hits > 0, "memo hit-rate must be > 0");
    let costed = r.points.iter().filter(|p| !p.pruned()).count();
    assert_eq!(r.distinct_plans + r.memo_hits, costed);
    assert_eq!(costed + r.pruned, r.points.len());
    // the frontier is non-empty and the argmin is on it
    assert!(!r.frontier.is_empty());
    assert!(r.frontier.contains(&r.best));
}

/// Every frontier must be budget-sorted, strictly improving in time,
/// and non-dominated against *all* costed points.
fn assert_frontier_valid(points: &[GridPoint], frontier: &[usize]) {
    let f: Vec<&GridPoint> = frontier.iter().map(|&i| &points[i]).collect();
    for w in f.windows(2) {
        assert!(w[0].budget_mb < w[1].budget_mb, "frontier not budget-sorted");
        assert!(
            w[0].cost_secs.unwrap() > w[1].cost_secs.unwrap(),
            "frontier not strictly improving"
        );
    }
    for fp in &f {
        for q in points.iter().filter(|p| !p.pruned()) {
            let (fb, fc) = (fp.budget_mb, fp.cost_secs.unwrap());
            let (qb, qc) = (q.budget_mb, q.cost_secs.unwrap());
            let dominates = (qb <= fb && qc < fc) || (qb < fb && qc <= fc);
            assert!(
                !dominates,
                "frontier point {} ({fb}MB, {fc}s) dominated by {} ({qb}MB, {qc}s)",
                fp.label(),
                q.label()
            );
        }
    }
}

#[test]
fn frontier_is_non_dominated_on_the_cg_grid() {
    let r = optimize_grid(&cg_grid(&Scenario::xl1(), 20)).unwrap();
    assert_frontier_valid(&r.points, &r.frontier);
    // the frontier's last point is the argmin
    assert_eq!(*r.frontier.last().unwrap(), r.best);
}

/// Property: across random data sizes and axis subsets, the frontier is
/// sorted and non-dominated.
#[test]
fn prop_frontier_non_dominated() {
    let heap_pool = [256.0, 512.0, 1024.0, 2048.0, 8192.0];
    forall(
        10,
        0xF007,
        |r| {
            let rows = r.range_i64(1, 50) * 100_000;
            let cols = r.range_i64(1, 10) * 100;
            let h1 = heap_pool[r.below(5) as usize];
            let h2 = heap_pool[r.below(5) as usize];
            let nodes = vec![1 + r.below(4) as usize, 1 + r.below(8) as usize];
            (rows, cols, h1, h2, nodes)
        },
        |&(rows, cols, h1, h2, ref nodes)| {
            let s = Scenario::xs();
            let mut g = ResourceGrid::new(
                LINREG_DS,
                s.args(),
                DataScenario::linreg("R", rows, cols),
            );
            g.heaps_mb = vec![h1, h2];
            g.nodes = nodes.clone();
            g.threads = 2;
            let r = optimize_grid(&g)?;
            assert_frontier_valid(&r.points, &r.frontier);
            Ok(())
        },
    );
}

#[test]
fn pruning_changes_neither_argmin_nor_frontier() {
    // XL1 on the DS script: the 800 GB persistent read floors the CP
    // points at ~5000 s, which the distributed points beat at smaller
    // budgets — so pruning must actually fire here...
    let s = Scenario::xl1();
    let mut g = ResourceGrid::new(LINREG_DS, s.args(), DataScenario::from(&s));
    g.threads = 4;
    let pruned = optimize_grid(&g).unwrap();
    assert!(pruned.pruned > 0, "expected the read floor to prune CP points");
    // ...and must not change any reported result
    g.prune = false;
    let full = optimize_grid(&g).unwrap();
    assert_eq!(full.pruned, 0);
    assert_eq!(pruned.best().label(), full.best().label());
    assert_eq!(pruned.best().cost_secs, full.best().cost_secs);
    let fa: Vec<(String, Option<f64>)> =
        pruned.frontier_points().map(|p| (p.label(), p.cost_secs)).collect();
    let fb: Vec<(String, Option<f64>)> =
        full.frontier_points().map(|p| (p.label(), p.cost_secs)).collect();
    assert_eq!(fa, fb, "pruning altered the frontier");
    // pruned points are exactly the ones whose floor can never win
    for (p, q) in pruned.points.iter().zip(&full.points) {
        if p.pruned() {
            assert!(
                q.cost_secs.unwrap() >= p.floor_secs,
                "pruned point {} cost {} below its floor {}",
                p.label(),
                q.cost_secs.unwrap(),
                p.floor_secs
            );
        } else {
            assert_eq!(p.cost_secs, q.cost_secs);
        }
    }
}

#[test]
fn grid_is_deterministic_across_thread_counts() {
    let mut one = cg_grid(&Scenario::xl1(), 10);
    one.threads = 1;
    let mut many = cg_grid(&Scenario::xl1(), 10);
    many.threads = 8;
    let a = optimize_grid(&one).unwrap();
    let b = optimize_grid(&many).unwrap();
    assert_eq!(a.frontier_table(), b.frontier_table());
    assert_eq!(a.best, b.best);
    assert_eq!(a.pruned, b.pruned);
    for (pa, pb) in a.points.iter().zip(&b.points) {
        match (pa.cost_secs, pb.cost_secs) {
            (Some(ca), Some(cb)) => assert_eq!(ca.to_bits(), cb.to_bits(), "{}", pa.label()),
            (None, None) => {}
            _ => panic!("pruning diverged across thread counts for {}", pa.label()),
        }
        assert_eq!(pa.plan_reused, pb.plan_reused);
    }
}

#[test]
fn api_wrapper_matches_engine() {
    let g = cg_grid(&Scenario::xs(), 5);
    let via_api = optimize_resources(&g).unwrap();
    let direct = optimize_grid(&g).unwrap();
    assert_eq!(via_api.frontier_table(), direct.frontier_table());
    assert_eq!(via_api.summary_shape(), direct.summary_shape());
}

/// Deterministic parts of the summary (everything but wall time).
trait SummaryShape {
    fn summary_shape(&self) -> (usize, usize, usize, usize, usize);
}
impl SummaryShape for systemds::api::ResourceReport {
    fn summary_shape(&self) -> (usize, usize, usize, usize, usize) {
        (self.points.len(), self.distinct_plans, self.memo_hits, self.pruned, self.frontier.len())
    }
}

// ---------------------------------------------------------------------
// NaN-safety regressions (the three bugfixes)
// ---------------------------------------------------------------------

#[test]
fn degenerate_base_configs_are_rejected_with_diagnostics() {
    let s = Scenario::xs();
    // zero client heap: used to poison spark_exec_ratio with NaN
    let mut g = cg_grid(&s, 5);
    g.base.cp_heap_bytes = 0.0;
    let err = optimize_grid(&g).unwrap_err();
    assert!(err.contains("cp_heap_bytes"), "{err}");
    // k_local = 0: used to make the parfor divisor inf
    let mut g = cg_grid(&s, 5);
    g.base.k_local = 0;
    let err = optimize_grid(&g).unwrap_err();
    assert!(err.contains("k_local"), "{err}");
    // zero disk bandwidth: used to make IO terms inf/NaN
    let mut g = cg_grid(&s, 5);
    g.constants.hdfs_read_binaryblock = 0.0;
    let err = optimize_grid(&g).unwrap_err();
    assert!(err.contains("hdfs_read_binaryblock"), "{err}");
    // degenerate axis values
    let mut g = cg_grid(&s, 5);
    g.k_local = vec![0];
    assert!(optimize_grid(&g).is_err());
    let mut g = cg_grid(&s, 5);
    g.heaps_mb = vec![-512.0];
    assert!(optimize_grid(&g).is_err());
    let mut g = cg_grid(&s, 5);
    g.backends.clear();
    assert!(optimize_grid(&g).is_err());
}

#[test]
fn legacy_heap_sweep_rejects_degenerate_configs() {
    use systemds::opt::resource::optimize_backend;
    let s = Scenario::xs();
    let mut cc = ClusterConfig::paper_cluster();
    cc.cp_heap_bytes = 0.0;
    let err = optimize_backend(
        s.script(),
        &s.args(),
        &s.meta(1000),
        &cc,
        &[512.0],
        ExecBackend::Spark,
    )
    .unwrap_err();
    assert!(err.contains("cp_heap_bytes"), "{err}");
    let mut cc = ClusterConfig::paper_cluster();
    cc.k_local = 0;
    assert!(optimize_backend(
        s.script(),
        &s.args(),
        &s.meta(1000),
        &cc,
        &[512.0],
        ExecBackend::Mr
    )
    .is_err());
    // degenerate heap values on a valid base are rejected too
    let cc = ClusterConfig::paper_cluster();
    assert!(optimize_backend(
        s.script(),
        &s.args(),
        &s.meta(1000),
        &cc,
        &[f64::NAN],
        ExecBackend::Mr
    )
    .is_err());
}

/// Zero-iteration While regression, end to end: with `N̂ = 0` the While
/// block charges only its predicate, so the program total must not
/// include the (0.5 s+) first-iteration read of X.
#[test]
fn zero_iteration_while_costs_only_predicate_time() {
    let src = "X = read($1);\ns = 1;\nwhile (s < 10) { s = s + sum(X); }\nwrite(s, $4);";
    let s = Scenario::xs();
    let opts = CompileOptions::default();
    let c = compile_with_meta(src, &s.args(), &s.meta(1000), &opts).unwrap();
    let mut cfg = opts.cfg.clone();
    cfg.unknown_iterations = 0.0;
    let zero =
        cost::cost_program(&c.runtime, &cfg, &opts.cc.0, &CostConstants::default()).total;
    cfg.unknown_iterations = 10.0;
    let ten = cost::cost_program(&c.runtime, &cfg, &opts.cc.0, &CostConstants::default()).total;
    assert!(zero < 0.05, "N̂=0 must not charge the loop body, got {zero}");
    assert!(ten > 0.5, "N̂=10 pays the first-iteration read, got {ten}");
}

// ---------------------------------------------------------------------
// The pruning bound
// ---------------------------------------------------------------------

/// Property: the persistent-read IO floor is a true lower bound on the
/// full cost-model estimate, across random scenario sizes, cluster
/// shapes, scripts and all three backends.
#[test]
fn prop_read_floor_is_a_lower_bound() {
    forall(
        15,
        0xF100,
        |r| {
            let rows = r.range_i64(1, 80) * 100_000;
            let cols = r.range_i64(1, 20) * 100;
            let heap = [256.0, 512.0, 2048.0, 8192.0][r.below(4) as usize];
            let nodes = 1 + r.below(10) as usize;
            let script_cg = r.below(2) == 1;
            (rows, cols, heap, nodes, script_cg)
        },
        |&(rows, cols, heap, nodes, script_cg)| {
            let cfg = SystemConfig::default();
            let k = CostConstants::default();
            let cc = ClusterConfig::paper_cluster().with_heap_mb(heap).with_nodes(nodes);
            let scenario = DataScenario::linreg("R", rows, cols);
            let inputs = vec![
                (MatrixCharacteristics::dense(rows, cols, cfg.blocksize), Format::BinaryBlock),
                (MatrixCharacteristics::dense(rows, 1, cfg.blocksize), Format::BinaryBlock),
            ];
            let (src, args) = if script_cg {
                (LINREG_CG, linreg_cg_args(5))
            } else {
                (LINREG_DS, Scenario::xs().args())
            };
            for backend in ExecBackend::all() {
                let opts = CompileOptions {
                    cfg: cfg.clone(),
                    cc: ClusterConfigOpt(cc.clone()),
                    backend,
                    ..Default::default()
                };
                let c = compile_with_meta(src, &args, &scenario.meta(cfg.blocksize), &opts)?;
                let total = cost::cost_program(&c.runtime, &cfg, &cc, &k).total;
                let floor = cost::read_io_floor(&inputs, backend, &cfg, &cc, &k);
                if floor > total {
                    return Err(format!(
                        "{}x{cols} heap={heap} nodes={nodes} {}: floor {floor} > cost {total}",
                        rows,
                        backend.name()
                    ));
                }
            }
            Ok(())
        },
    );
}
