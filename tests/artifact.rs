//! Integration tests for the persistent artifact layer (`src/artifact/`):
//! filesystem round-trips for all three artifact kinds, the
//! corruption/truncation/unknown-kind diagnostics (always an `Err`, never
//! a panic), the regenerate-on-mismatch rule for stale plan payloads, and
//! the cost-cache snapshot replay contract — a warm-from-disk optimizer
//! run must reproduce the cold run's argmin and costs *bitwise* while
//! serving nearly every costing from the loaded cache.

use std::path::PathBuf;

use systemds::api::{
    calibrate, load_artifact, save_artifact, Artifact, CacheSnapshot, CalibrateOptions,
    CalibrationProfile, CompileOptions, DataScenario, Evaluator, GdfSpec, MeasureMode,
    PlanArtifact, Scenario, PLAN_FORMAT_VERSION,
};
use systemds::conf::CostConstants;
use systemds::matrix::Format;
use systemds::opt::gdf;

/// Per-test scratch file under a pid-unique directory, so concurrent
/// test binaries never race on the same artifact paths.
fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sysds_artifact_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create artifact test dir");
    dir.join(name)
}

/// A cheap plan artifact: the XS scenario under default options.
fn xs_plan() -> PlanArtifact {
    let s = Scenario::xs();
    let opts = CompileOptions::default();
    PlanArtifact::capture(
        s.script(),
        &s.args(),
        &s.meta(opts.cfg.blocksize),
        &opts,
        &CostConstants::default(),
    )
    .expect("capture xs plan")
}

/// The reference GDF search space (mirrors tests/gdf.rs): LinReg CG on
/// XL1 with a small deterministic axis set.
fn cg_spec(threads: usize) -> GdfSpec {
    let s = Scenario::xl1();
    let mut spec = GdfSpec::linreg_cg(DataScenario::from(&s), 20);
    spec.blocksizes = vec![1000, 2000];
    spec.formats = vec![Format::BinaryBlock];
    spec.partitions_mb = vec![32.0];
    spec.threads = threads;
    spec
}

fn simulated_opts(seed: u64) -> CalibrateOptions {
    CalibrateOptions {
        seed,
        quick: true,
        threads: 1,
        mode: MeasureMode::Simulated { noise: 0.0 },
        ..Default::default()
    }
}

// ---------------------------------------------------------------------
// Round trips through the filesystem
// ---------------------------------------------------------------------

/// Encode → save → load → decode is the identity for a plan artifact:
/// the re-encoded text is byte-identical and the synthesized costs
/// survive bitwise.
#[test]
fn plan_artifact_round_trips_through_the_filesystem() {
    let plan = xs_plan();
    assert!(plan.total.is_finite() && plan.total > 0.0);
    assert!(!plan.blocks.is_empty());
    assert!(!plan.explain.is_empty());

    let encoded = plan.encode();
    let path = tmp("roundtrip.plan");
    save_artifact(&path, &Artifact::Plan(plan.clone())).expect("save plan");
    let loaded = match load_artifact(&path).expect("load plan") {
        Artifact::Plan(p) => p,
        other => panic!("expected a plan artifact, got kind '{}'", other.kind()),
    };

    assert_eq!(loaded.encode(), encoded, "re-encode must be byte-identical");
    assert_eq!(loaded.script, plan.script);
    assert_eq!(loaded.args, plan.args);
    assert_eq!(loaded.inputs, plan.inputs);
    assert_eq!(loaded.root, plan.root);
    assert_eq!(loaded.total.to_bits(), plan.total.to_bits(), "total must survive bitwise");
    assert_eq!(loaded.blocks.len(), plan.blocks.len());
    for ((ha, ca), (hb, cb)) in loaded.blocks.iter().zip(&plan.blocks) {
        assert_eq!(ha, hb);
        assert_eq!(ca.to_bits(), cb.to_bits(), "block costs must survive bitwise");
    }
    assert_eq!(loaded.explain, plan.explain);

    // and the loaded artifact validates clean: same stable section, same
    // structural hash, nothing to regenerate
    let checked = loaded.load_checked().expect("recompile stable section");
    assert!(!checked.regenerated, "fresh round trip must not regenerate: {:?}", checked.reason);
    assert!(checked.plan_unchanged());
}

/// A calibration profile survives the filesystem with its calibrated
/// constants intact (bitwise, via `PartialEq` over every f64 field).
#[test]
fn profile_round_trips_and_preserves_calibrated_constants() {
    let opts = simulated_opts(42);
    let report = calibrate(&opts).expect("simulated calibration");
    let profile = CalibrationProfile::from_report(&report, &opts);
    assert_eq!(profile.constants(), &report.calibrated);

    let path = tmp("roundtrip.profile");
    save_artifact(&path, &Artifact::Profile(profile.clone())).expect("save profile");
    let loaded = match load_artifact(&path).expect("load profile") {
        Artifact::Profile(p) => p,
        other => panic!("expected a profile artifact, got kind '{}'", other.kind()),
    };

    assert_eq!(loaded.encode(), profile.encode(), "re-encode must be byte-identical");
    assert_eq!(loaded.constants(), &report.calibrated, "calibrated constants must survive");
    assert_eq!(loaded.corrections, report.corrections);
    assert_eq!(loaded.seed, 42);
    assert!(loaded.summary().contains("seed=42"), "{}", loaded.summary());
}

// ---------------------------------------------------------------------
// Diagnostics: corrupted, truncated, unknown — never a panic
// ---------------------------------------------------------------------

/// Every malformed input is a diagnostic `Err` naming the problem; a
/// half-written or bit-flipped artifact can never be half-loaded.
#[test]
fn corrupted_truncated_and_unknown_artifacts_fail_with_diagnostics() {
    let text = Artifact::Plan(xs_plan()).encode();

    // bit flip inside the body -> checksum mismatch
    let corrupted = text.replacen("stable", "stab1e", 1);
    let err = Artifact::decode(&corrupted).unwrap_err();
    assert!(err.contains("checksum mismatch"), "{err}");

    // truncation -> missing/mismatched checksum, named as such
    for cut in [text.len() / 4, text.len() / 2, text.len() - 8] {
        let err = Artifact::decode(&text[..cut]).unwrap_err();
        assert!(err.contains("checksum") || err.contains("truncated"), "cut {cut}: {err}");
    }

    // unknown artifact kind (a valid container from a newer build):
    // the checksum passes, the dispatch names the kind it cannot read
    let mut w = systemds::artifact::codec::Writer::new("hologram");
    w.section("meta");
    w.put_u64("v", 1);
    let err = Artifact::decode(&w.finish()).unwrap_err();
    assert!(err.contains("unknown kind 'hologram'"), "{err}");

    // unsupported container version
    let mut w = systemds::artifact::codec::Writer::new("plan");
    w.section("stable");
    w.put_u64("synth_version", 1);
    let v2 = w.finish().replacen("#! sysds-artifact v1", "#! sysds-artifact v9", 1);
    let err = Artifact::decode(&v2).unwrap_err();
    assert!(err.contains("checksum") || err.contains("version"), "{err}");

    // not an artifact at all, and a missing file on the fs path
    assert!(Artifact::decode("definitely not an artifact").is_err());
    let err = load_artifact(&tmp("does_not_exist.plan")).unwrap_err();
    assert!(err.contains("cannot read"), "{err}");
}

// ---------------------------------------------------------------------
// Regenerate-on-mismatch
// ---------------------------------------------------------------------

/// A stale synthesized payload — wrong format version or tampered
/// costs — is regenerated from the stable section on load, through a
/// full save/load cycle, and the regenerated costs match a fresh
/// capture bitwise.
#[test]
fn stale_synthesized_sections_are_regenerated_through_the_fs() {
    let fresh = xs_plan();

    // poison the payload: old version, garbage total, garbage explain
    let mut stale = fresh.clone();
    stale.synth_version = PLAN_FORMAT_VERSION + 1;
    stale.total = -1.0;
    stale.explain = "stale explain".to_string();

    let path = tmp("stale.plan");
    save_artifact(&path, &Artifact::Plan(stale)).expect("save stale plan");
    let loaded = match load_artifact(&path).expect("load stale plan") {
        Artifact::Plan(p) => p,
        other => panic!("expected a plan artifact, got kind '{}'", other.kind()),
    };
    let checked = loaded.load_checked().expect("recompile stable section");

    assert!(checked.regenerated, "version mismatch must force regeneration");
    let reason = checked.reason.as_deref().unwrap_or_default();
    assert!(reason.contains("version"), "{reason}");
    assert_eq!(checked.stored_explain, "stale explain");
    assert_eq!(
        checked.artifact.total.to_bits(),
        fresh.total.to_bits(),
        "regenerated total must match a fresh capture bitwise"
    );
    assert_eq!(checked.artifact.synth_version, PLAN_FORMAT_VERSION);
    assert_eq!(checked.artifact.explain, fresh.explain);
    assert!(!checked.plan_unchanged());
    let diff = checked.explain_diff();
    assert!(diff.contains("- ") && diff.contains("+ "), "{diff}");
}

// ---------------------------------------------------------------------
// Cost-cache snapshot replay
// ---------------------------------------------------------------------

/// The acceptance contract behind `--warm-cache`: run the GDF optimizer
/// cold, snapshot its cost cache to disk, load the snapshot into a fresh
/// evaluator, and re-run — the warm run must reproduce the cold argmin
/// and every candidate cost bitwise, serving ≥90% of block costings from
/// the loaded cache.
#[test]
fn snapshot_round_trip_replays_bitwise_identical_costs() {
    let spec = cg_spec(2);

    let mut cold = Evaluator::new(2);
    let cold_report = gdf::optimize_with(&spec, &mut cold).expect("cold gdf run");
    let cache = cold.cache().expect("default evaluator keeps a cost cache");
    let snap = CacheSnapshot::from_cache(&cache);
    assert!(!snap.is_empty(), "cold run must populate the cache");
    assert!(snap.capacity() >= snap.len());

    let path = tmp("warm.costcache");
    save_artifact(&path, &Artifact::CacheSnapshot(snap)).expect("save snapshot");
    let loaded = match load_artifact(&path).expect("load snapshot") {
        Artifact::CacheSnapshot(s) => s,
        other => panic!("expected a cost-cache snapshot, got kind '{}'", other.kind()),
    };

    let mut warm = Evaluator::with_cache(2, Some(loaded.into_cache()));
    let warm_report = gdf::optimize_with(&spec, &mut warm).expect("warm gdf run");

    assert_eq!(
        cold_report.best().label(),
        warm_report.best().label(),
        "warm-from-disk must reproduce the cold argmin"
    );
    assert_eq!(cold_report.candidates.len(), warm_report.candidates.len());
    for (a, b) in cold_report.candidates.iter().zip(&warm_report.candidates) {
        assert_eq!(a.label(), b.label());
        assert_eq!(
            a.cost_secs.to_bits(),
            b.cost_secs.to_bits(),
            "candidate '{}' cost must replay bitwise",
            a.label()
        );
    }

    let stats = warm.run_cache_stats();
    assert!(
        stats.hit_rate() >= 0.9,
        "warm-from-disk hit rate {:.3} below 0.9 ({} hits / {} misses)",
        stats.hit_rate(),
        stats.hits,
        stats.misses
    );
}

/// Applying a snapshot into an existing cache merges entries (the
/// shard-merge path) rather than replacing them, and a decoded snapshot
/// re-encodes byte-identically.
#[test]
fn snapshot_encode_is_stable_and_apply_merges() {
    let spec = cg_spec(1);
    let mut eval = Evaluator::new(1);
    gdf::optimize_with(&spec, &mut eval).expect("gdf run");
    let cache = eval.cache().expect("cost cache");
    let snap = CacheSnapshot::from_cache(&cache);
    let encoded = snap.encode();

    let decoded = CacheSnapshot::decode(&encoded).expect("decode snapshot");
    assert_eq!(decoded.len(), snap.len());
    assert_eq!(decoded.encode(), encoded, "re-encode must be byte-identical");

    // merging the snapshot back into the cache it came from changes
    // nothing: every entry is already present
    let before = cache.stats().entries;
    decoded.apply(&cache);
    assert_eq!(cache.stats().entries, before, "self-merge must not grow the cache");

    // merging into an empty cache restores every entry
    let restored = decoded.into_cache();
    assert_eq!(restored.stats().entries, snap.len());
}
