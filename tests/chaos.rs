//! Chaos battery for failure-aware costing and deterministic fault
//! injection (`conf::FaultProfile`, `cost::*_faults`, the `mr::`
//! simulator's seeded schedules):
//!
//! * **The checked-in flip** — under the in-process
//!   [`simulator_truth`] constants the distributed plans win
//!   [`REOPT_CASE`] fault-free; pricing the bundled chaos profile flips
//!   the backend argmin to CP. `repro chaos` (and the CI chaos smoke)
//!   confirms the same flip by *executing* both winners under injected
//!   faults; this test pins the pricing side hermetically.
//! * **Bitwise replay** — a seeded fault schedule is keyed
//!   `(seed, job, task, attempt)` and drawn before the thread pool
//!   runs, so whole-program chaos runs report identical counters and
//!   delay ledgers across worker counts.
//! * **Disarmed identity** — `FaultProfile::none()` is a no-op both for
//!   costing (bitwise) and execution (zero counters, empty ledger).
//! * **Monotonicity property** — expected cost never decreases in the
//!   per-attempt failure probability or the straggler fraction, for
//!   random shapes, heaps, and distributed backends.

use std::collections::HashMap;

use systemds::api::{compile, compile_with_meta, ClusterConfigOpt, CompileOptions, Scenario};
use systemds::conf::{CostConstants, FaultProfile};
use systemds::cost;
use systemds::cp::interp::{ExecStats, Executor};
use systemds::feedback::runner::cluster_for;
use systemds::feedback::{bundled_cases, simulator_truth, CalibrationCase, REOPT_CASE};
use systemds::ir::build::StaticMeta;
use systemds::matrix::{io, ops, DenseMatrix, Format, MatrixCharacteristics};
use systemds::rtprog::{ExecBackend, RtProgram};
use systemds::util::prop::forall;

/// Per-test scratch directory (tests run in parallel in one process).
fn scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("sysds_chaos_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Compile [`REOPT_CASE`] for one backend on the fixed 8-slot geometry
/// `repro chaos` uses — metadata-only, no data files needed to cost.
fn compile_reopt(backend: ExecBackend) -> (RtProgram, CompileOptions) {
    let cc = cluster_for(8, &REOPT_CASE);
    let opts = CompileOptions {
        cc: ClusterConfigOpt(cc),
        backend,
        ..Default::default()
    };
    let mut args = HashMap::new();
    args.insert(1, "chaos/X".to_string());
    args.insert(2, "chaos/y".to_string());
    args.insert(3, "0".to_string());
    args.insert(4, "chaos/out".to_string());
    let meta = StaticMeta::default()
        .with(
            "chaos/X",
            MatrixCharacteristics::dense(
                REOPT_CASE.rows as i64,
                REOPT_CASE.cols as i64,
                opts.cfg.blocksize,
            ),
            Format::BinaryBlock,
        )
        .with(
            "chaos/y",
            MatrixCharacteristics::dense(REOPT_CASE.rows as i64, 1, opts.cfg.blocksize),
            Format::BinaryBlock,
        );
    let compiled =
        compile_with_meta(REOPT_CASE.script, &args, &meta, &opts).expect("compile reopt case");
    (compiled.runtime, opts)
}

/// The checked-in chaos scenario: fault-free, a distributed backend wins
/// `REOPT_CASE` under the in-process constants; priced under the bundled
/// chaos profile, the argmin flips to CP. The disarmed profile stays
/// bitwise-invisible and pricing failures never makes a plan cheaper.
#[test]
fn chaos_pricing_flips_the_reopt_argmin_to_cp() {
    let k = simulator_truth();
    let chaos = FaultProfile::chaos();
    let mut plain: Vec<(ExecBackend, f64)> = Vec::new();
    let mut faulty: Vec<(ExecBackend, f64)> = Vec::new();
    for backend in ExecBackend::all() {
        let (rt, opts) = compile_reopt(backend);
        let p = cost::cost_total(&rt, &opts.cfg, &opts.cc.0, &k);
        let f = cost::cost_total_faults(&rt, &opts.cfg, &opts.cc.0, &k, &chaos);
        let disarmed =
            cost::cost_total_faults(&rt, &opts.cfg, &opts.cc.0, &k, &FaultProfile::none());
        assert_eq!(
            disarmed.to_bits(),
            p.to_bits(),
            "{backend:?}: FaultProfile::none must be bitwise-invisible"
        );
        assert!(f >= p, "{backend:?}: pricing failures must never cut cost ({f} < {p})");
        plain.push((backend, p));
        faulty.push((backend, f));
    }
    let argmin = |v: &[(ExecBackend, f64)]| {
        v.iter().min_by(|a, b| a.1.total_cmp(&b.1)).expect("three backends").0
    };
    let before = argmin(&plain);
    let after = argmin(&faulty);
    assert_ne!(
        before,
        ExecBackend::Cp,
        "fault-free argmin must be distributed under simulator-truth constants: {plain:?}"
    );
    assert_eq!(
        after,
        ExecBackend::Cp,
        "chaos pricing must flip the argmin to CP: {faulty:?}"
    );
    // A pure-CP plan runs no distributed tasks, so there is nothing for
    // the chaos profile to retry: its price is bitwise unchanged.
    let cp = |v: &[(ExecBackend, f64)]| {
        v.iter().find(|(b, _)| *b == ExecBackend::Cp).expect("cp candidate").1
    };
    assert_eq!(cp(&plain).to_bits(), cp(&faulty).to_bits());
}

/// Generate the case's data under `dir`, compile against its bundled
/// cluster (same shape as `tests/accuracy.rs`), and return the plan.
fn compile_case(
    case: &CalibrationCase,
    dir: &std::path::Path,
    threads: usize,
) -> (RtProgram, CompileOptions) {
    let x = DenseMatrix::rand(case.rows, case.cols, -1.0, 1.0, 1.0, 42);
    let beta = DenseMatrix::rand(case.cols, 1, -0.5, 0.5, 1.0, 43);
    let y = ops::matmult(&x, &beta, threads);
    let xp = dir.join("X").to_string_lossy().to_string();
    let yp = dir.join("y").to_string_lossy().to_string();
    io::write_binary_block(&xp, &x, 1000).unwrap();
    io::write_binary_block(&yp, &y, 1000).unwrap();
    let mut args = HashMap::new();
    args.insert(1, xp);
    args.insert(2, yp);
    args.insert(3, case.iters.to_string());
    args.insert(4, dir.join("out").to_string_lossy().to_string());
    let cc = cluster_for(threads, case);
    let opts = CompileOptions { cc: ClusterConfigOpt(cc), ..Default::default() };
    let compiled = compile(case.script, &args, &opts).expect("compile bundled case");
    (compiled.runtime, opts)
}

/// The bundled distributed calibration case (tiny task heap, so the
/// whole LinReg pipeline runs as simulated MR jobs).
fn mr_case() -> CalibrationCase {
    let case = bundled_cases(true)[2];
    assert!(case.heap_mb < 1.0, "expected the tiny-heap MR case, got {case:?}");
    case
}

/// Deterministic counters of one armed whole-program run.
fn chaos_counters(stats: &ExecStats) -> (usize, usize, usize, u64) {
    (
        stats.failed_attempts,
        stats.straggler_tasks,
        stats.speculative_copies,
        stats.fault_delay_secs.to_bits(),
    )
}

/// Whole-program chaos runs replay bitwise across worker counts: the
/// fault schedule is keyed `(seed, job, task, attempt)` and drawn before
/// the pool runs, so only wall-clock may differ between a 1-thread and a
/// 4-thread execution of the same plan — and some seed in a short
/// deterministic scan must actually inject events.
#[test]
fn program_fault_schedule_replays_bitwise_across_thread_counts() {
    let case = mr_case();
    let dir = scratch("replay");
    let (rt, opts) = compile_case(&case, &dir, 4);
    let chaos = FaultProfile::chaos();
    let run = |threads: usize, seed: u64, tag: &str| -> ExecStats {
        let cc = cluster_for(threads, &case);
        let mut exec = Executor::new(&opts.cfg, &cc, None, dir.join(tag));
        exec.set_fault_injection(chaos.clone(), seed);
        exec.run(&rt).expect("chaos run completes")
    };
    let mut hit = None;
    for seed in 42..42 + 16 {
        let s1 = run(1, seed, &format!("t1_s{seed}"));
        let s4 = run(4, seed, &format!("t4_s{seed}"));
        assert_eq!(
            chaos_counters(&s1),
            chaos_counters(&s4),
            "seed {seed}: counters and delay ledger must replay bitwise across threads"
        );
        assert_eq!(s1.mr_jobs, s4.mr_jobs);
        assert_eq!(s1.map_tasks, s4.map_tasks);
        if s1.failed_attempts > 0 {
            hit = Some((seed, s1));
            break;
        }
    }
    let (seed, s1) = hit.expect("chaos at 8% per-attempt failure must hit within 16 seeds");
    // A failed attempt pays at least one backoff interval into the
    // simulated delay ledger.
    assert!(
        s1.fault_delay_secs >= chaos.backoff_base,
        "seed {seed}: {} failed attempts accrued only {}s of delay",
        s1.failed_attempts,
        s1.fault_delay_secs
    );
    // Replaying the exact run reproduces the exact schedule.
    let again = run(1, seed, &format!("t1_s{seed}_again"));
    assert_eq!(chaos_counters(&s1), chaos_counters(&again));
}

/// Arming the executor with the disarmed profile is indistinguishable
/// from never arming it: zero fault counters, empty delay ledger, and
/// identical deterministic work counters.
#[test]
fn disarmed_profile_executes_identically_to_no_injection() {
    let case = mr_case();
    let dir = scratch("disarmed");
    let (rt, opts) = compile_case(&case, &dir, 2);
    let cc = cluster_for(2, &case);

    let mut plain = Executor::new(&opts.cfg, &cc, None, dir.join("plain"));
    let sp = plain.run(&rt).expect("plain run completes");

    let mut armed = Executor::new(&opts.cfg, &cc, None, dir.join("armed"));
    armed.set_fault_injection(FaultProfile::none(), 42);
    let sa = armed.run(&rt).expect("disarmed run completes");

    for s in [&sp, &sa] {
        assert_eq!(s.failed_attempts, 0);
        assert_eq!(s.straggler_tasks, 0);
        assert_eq!(s.speculative_copies, 0);
        assert_eq!(s.fault_delay_secs, 0.0);
    }
    assert_eq!(sp.cp_insts, sa.cp_insts);
    assert_eq!(sp.mr_jobs, sa.mr_jobs);
    assert_eq!(sp.map_tasks, sa.map_tasks);
    assert_eq!(sp.shuffle_bytes.to_bits(), sa.shuffle_bytes.to_bits());
    assert_eq!(sp.hdfs_read_bytes.to_bits(), sa.hdfs_read_bytes.to_bits());
    assert_eq!(sp.hdfs_write_bytes.to_bits(), sa.hdfs_write_bytes.to_bits());
}

/// Compile one LinReg plan for a random shape/heap/backend (same helper
/// shape as `tests/properties.rs`).
fn compile_random_backend(
    rows: i64,
    cols: i64,
    heap_mb: f64,
    backend: ExecBackend,
) -> (RtProgram, CompileOptions) {
    use systemds::conf::{ClusterConfig, SystemConfig, MB};
    let mut cc = ClusterConfig::paper_cluster();
    cc.cp_heap_bytes = heap_mb * MB;
    cc.map_heap_bytes = heap_mb * MB;
    let opts = CompileOptions {
        cc: ClusterConfigOpt(cc),
        cfg: SystemConfig::default(),
        backend,
        ..Default::default()
    };
    let meta = StaticMeta::default()
        .with("data/X", MatrixCharacteristics::dense(rows, cols, 1000), Format::BinaryBlock)
        .with("data/y", MatrixCharacteristics::dense(rows, 1, 1000), Format::BinaryBlock);
    let c = compile_with_meta(
        systemds::api::LINREG_DS,
        &Scenario::xs().args(),
        &meta,
        &opts,
    )
    .expect("compile random scenario");
    (c.runtime, opts)
}

/// Expected cost under failures is monotone: raising the per-attempt
/// failure probability or the straggler fraction never makes a plan
/// cheaper, and the disarmed profile is the bitwise anchor of the
/// ladder — for random shapes, heaps, and distributed backends.
#[test]
fn prop_fault_pricing_is_monotone_in_failure_severity() {
    let k = CostConstants::default();
    forall(
        12,
        0xFA17,
        |rng| {
            let rows = 512 + rng.below(8192) as i64;
            let cols = 32 + rng.below(224) as i64;
            let heap_mb = if rng.below(2) == 0 { 0.12 } else { 64.0 };
            let backend =
                if rng.below(2) == 0 { ExecBackend::Mr } else { ExecBackend::Spark };
            let p_lo = rng.below(10) as f64 / 100.0;
            let p_hi = p_lo + 0.05 + rng.below(10) as f64 / 100.0;
            let frac = rng.below(30) as f64 / 100.0;
            (rows, cols, heap_mb, backend, p_lo, p_hi, frac)
        },
        |&(rows, cols, heap_mb, backend, p_lo, p_hi, frac)| {
            let (rt, opts) = compile_random_backend(rows, cols, heap_mb, backend);
            let total = |fault: &FaultProfile| {
                cost::cost_total_faults(&rt, &opts.cfg, &opts.cc.0, &k, fault)
            };
            let fail_only = |p: f64| FaultProfile {
                mr_fail_p: p,
                spark_fail_p: p,
                max_attempts: 4,
                backoff_base: 0.5,
                ..FaultProfile::none()
            };
            let base = cost::cost_total(&rt, &opts.cfg, &opts.cc.0, &k);
            let anchored = total(&FaultProfile::none());
            if anchored.to_bits() != base.to_bits() {
                return Err(format!("none() not bitwise-invisible: {anchored} vs {base}"));
            }
            let lo = total(&fail_only(p_lo));
            let hi = total(&fail_only(p_hi));
            if lo < base || hi < lo {
                return Err(format!(
                    "cost not monotone in failure probability: base {base}, p={p_lo} -> {lo}, p={p_hi} -> {hi}"
                ));
            }
            let straggly = |f: f64| FaultProfile {
                straggler_frac: f,
                straggler_slowdown: 4.0,
                ..FaultProfile::none()
            };
            let tail = total(&straggly(frac));
            let taller = total(&straggly((frac + 0.2).min(1.0)));
            if tail < base || taller < tail {
                return Err(format!(
                    "cost not monotone in straggler fraction: base {base}, frac={frac} -> {tail}, frac+0.2 -> {taller}"
                ));
            }
            Ok(())
        },
    );
}
