"""AOT pipeline: lower the L2/L1 functions to HLO *text* artifacts for the
Rust PJRT runtime (`rust/src/runtime/`).

Interchange format is HLO text, NOT ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md and aot_recipe.md).

Artifact naming matches the Rust kernel registry
(``runtime::kernel_key``): ``<op>_<rows>x<cols>[_<rows>x<cols>].hlo.txt``.

Usage: python -m compile.aot [--out ../artifacts]
"""

import argparse
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(m, n):
    return jax.ShapeDtypeStruct((m, n), jnp.float64)


def shape_key(op, *shapes):
    return op + "".join(f"_{m}x{n}" for (m, n) in shapes)


# Shapes compiled ahead of time. These cover the executable scenarios of
# the accuracy suite (tests/accuracy.rs) plus the registry smoke test.
TSMM_SHAPES = [(256, 64), (2048, 128), (4096, 256), (8192, 256)]
MATMULT_SHAPES = [
    ((1, 2048), (2048, 128)),
    ((1, 4096), (4096, 256)),
    ((1, 8192), (8192, 256)),
]
SOLVE_SHAPES = [(64, 1), (128, 1), (256, 1)]
LINREG_SHAPES = [(2048, 128), (4096, 256)]


def build_artifacts(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    written = []

    def emit(key, fn, *args):
        path = os.path.join(out_dir, key + ".hlo.txt")
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        written.append(key)

    for (m, n) in TSMM_SHAPES:
        emit(shape_key("tsmm", (m, n)), lambda x: (model.tsmm(x),), spec(m, n))
    for ((am, an), (bm, bn)) in MATMULT_SHAPES:
        emit(
            shape_key("matmult", (am, an), (bm, bn)),
            lambda a, b: (model.matmult(a, b),),
            spec(am, an),
            spec(bm, bn),
        )
    for (n, r) in SOLVE_SHAPES:
        emit(
            shape_key("solve", (n, n), (n, r)),
            lambda a, b: (model.solve(a, b),),
            spec(n, n),
            spec(n, r),
        )
    for (m, n) in LINREG_SHAPES:
        emit(
            shape_key("linreg", (m, n)),
            lambda x, y: (model.linreg_ds(x, y),),
            spec(m, n),
            spec(m, 1),
        )
    return written


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    written = build_artifacts(args.out)
    print(f"wrote {len(written)} artifacts to {args.out}:")
    for k in written:
        print(f"  {k}")


if __name__ == "__main__":
    main()
