"""Pure-jnp correctness oracles for the Pallas kernels and the L2 model.

Every kernel in this package is validated against these references by
``python/tests/`` (pytest + hypothesis) before the AOT artifacts are built.
"""

import jax.numpy as jnp
import jax.scipy.linalg as jla


def tsmm_ref(x):
    """Transpose-self matrix multiply: t(X) %*% X."""
    return x.T @ x


def matmult_ref(a, b):
    """General matrix multiply."""
    return a @ b


def solve_ref(a, b):
    """Dense linear system solve."""
    return jla.solve(a, b)


def linreg_ds_ref(x, y, lam=0.001):
    """The paper's LinReg DS pipeline (§1): beta = solve(X'X + lam*I, X'y)."""
    n = x.shape[1]
    a = x.T @ x + lam * jnp.eye(n, dtype=x.dtype)
    b = x.T @ y
    return jla.solve(a, b)
