"""L1 Pallas kernel: blocked transpose-self matrix multiply (tsmm).

The paper's hottest operator (Eq. 2, `tsmm LEFT`, Figures 2-5) computes
``t(X) %*% X`` exploiting the symmetry of the result — "only half the
computation". This kernel is the TPU-idiomatic formulation of that insight
(DESIGN.md §Hardware-Adaptation):

* X is tiled into ``(bm, bn)`` VMEM blocks via ``BlockSpec`` — the HBM→VMEM
  schedule the original CPU/MR operator expressed with row-block scans.
* The grid walks output blocks ``(i, j)`` and row panels ``k``; each step
  accumulates ``X[k,i]ᵀ · X[k,j]`` on the MXU (``jnp.dot`` with a
  ``preferred_element_type`` accumulator).
* **Symmetry**: blocks strictly below the diagonal are skipped
  (``pl.when(j >= i)``) — half the MXU work, mirroring ``MMD_corr = 0.5``.
  The full result is reconstructed with a cheap transpose epilogue:
  ``triu(U) + triu(U, 1).T``.

CPU note: lowered with ``interpret=True`` — real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute; numeric validation
runs through the interpret path (see python/tests/test_kernel.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tsmm_kernel(x_i_ref, x_j_ref, o_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Symmetry: only the upper-triangular block panel is computed.
    @pl.when(j >= i)
    def _accumulate():
        o_ref[...] += jnp.dot(
            x_i_ref[...].T, x_j_ref[...], preferred_element_type=o_ref.dtype
        )


def _pad_to(x, bm, bn):
    """Zero-pad rows/cols to block multiples (exact for tsmm: zero rows
    contribute nothing, zero cols yield zero rows/cols we slice away)."""
    m, n = x.shape
    mp = (bm - m % bm) % bm
    np_ = (bn - n % bn) % bn
    if mp or np_:
        x = jnp.pad(x, ((0, mp), (0, np_)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def tsmm(x, bm=256, bn=128, interpret=True):
    """Compute ``t(X) %*% X`` with the blocked symmetric Pallas kernel."""
    m, n = x.shape
    bm = min(bm, max(m, 1))
    bn = min(bn, max(n, 1))
    xp = _pad_to(x, bm, bn)
    mp, np_ = xp.shape
    grid = (np_ // bn, np_ // bn, mp // bm)
    upper = pl.pallas_call(
        _tsmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, i)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), x.dtype),
        interpret=interpret,
    )(xp, xp)
    # transpose epilogue: mirror the strict upper triangle
    full = jnp.triu(upper) + jnp.triu(upper, 1).T
    return full[:n, :n]


def vmem_footprint_bytes(bm, bn, dtype_bytes=8):
    """Analytical VMEM footprint of one grid step (DESIGN.md §Perf):
    two input blocks + one accumulator block."""
    return (2 * bm * bn + bn * bn) * dtype_bytes


def mxu_utilization_estimate(m, n, bm, bn):
    """Fraction of issued MXU MACs that are useful: the symmetric skip
    leaves ceil(nb*(nb+1)/2) of nb^2 block-pairs active; within those,
    padding waste is (m*n)/(mp*np) per block."""
    nb = -(-n // bn)
    mp = -(-m // bm) * bm
    np_ = nb * bn
    active = nb * (nb + 1) / 2
    issued = active * bm * bn * bn * (mp // bm)
    useful = m * n * n * (n + 1) / (2 * n) if n else 0
    return min(1.0, useful / issued) if issued else 0.0
