"""L2 JAX model: the paper's LinReg DS compute graph, built on the L1
Pallas tsmm kernel. Lowered once by ``aot.py`` to HLO-text artifacts that
the Rust CP runtime executes via PJRT — Python never runs at request time.

The pipeline mirrors the generated XS runtime plan (paper Figure 2)
operator for operator:

* ``tsmm``   — `t(X) %*% X` via the symmetric Pallas kernel,
* ``(yᵀX)ᵀ`` — the HOP-LOP transpose rewrite instead of `t(X) %*% y`,
* ``solve``  — dense LU solve.
"""

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jla

jax.config.update("jax_enable_x64", True)

from .kernels import tsmm as tsmm_kernel  # noqa: E402


def tsmm(x, bm=None, bn=None):
    """t(X) %*% X (Pallas, interpret mode).

    Block sizes default to the *deployment profile*: on CPU-PJRT the
    interpret-mode grid overhead dominates, so the fastest configuration is
    a single full-matrix block (the kernel degenerates to one fused MXU/dot
    call — measured 2x faster than 4096-row panels, see EXPERIMENTS.md
    §Perf). The TPU-targeted profile is (256, 128) with the symmetric
    block-skip; its VMEM/MXU characteristics are modelled analytically in
    `tsmm.vmem_footprint_bytes` / `mxu_utilization_estimate`.
    """
    m, n = x.shape
    return tsmm_kernel.tsmm(x, bm=bm or m, bn=bn or n)


def matmult(a, b):
    """General matrix multiply (XLA dot)."""
    return a @ b


def solve(a, b):
    """Dense solve via LU."""
    return jla.solve(a, b)


def linreg_ds(x, y, lam=0.001):
    """Closed-form linear regression, the paper's running example.

    A    = t(X)%*%X + diag(matrix(lam, ncol(X), 1))   [tsmm + rewrite]
    b    = t(X)%*%y                                    [(y'X)' rewrite]
    beta = solve(A, b)
    """
    n = x.shape[1]
    a = tsmm(x) + lam * jnp.eye(n, dtype=x.dtype)
    b = matmult(y.T, x).T  # (y'X)' — Figure 2's rewrite
    return solve(a, b)
