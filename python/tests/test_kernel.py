"""L1 correctness: the Pallas tsmm kernel against the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes and block sizes; this is the CORE
correctness signal for the kernel before artifacts are built.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import ref  # noqa: E402
from compile.kernels import tsmm as tk  # noqa: E402


def _rand(m, n, seed, dtype):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((m, n)), dtype=dtype)


@pytest.mark.parametrize("m,n", [(8, 8), (64, 32), (256, 64), (300, 50), (128, 128)])
def test_tsmm_matches_ref(m, n):
    x = _rand(m, n, 0, jnp.float64)
    got = tk.tsmm(x)
    np.testing.assert_allclose(got, ref.tsmm_ref(x), rtol=1e-10, atol=1e-10)


@pytest.mark.parametrize("bm,bn", [(32, 16), (64, 64), (128, 32), (256, 128)])
def test_tsmm_block_shapes(bm, bn):
    x = _rand(200, 96, 1, jnp.float64)
    got = tk.tsmm(x, bm=bm, bn=bn)
    np.testing.assert_allclose(got, ref.tsmm_ref(x), rtol=1e-10, atol=1e-10)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=160),
    n=st.integers(min_value=1, max_value=96),
    bm=st.sampled_from([16, 32, 64, 128]),
    bn=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_tsmm_hypothesis_shapes(m, n, bm, bn, seed):
    x = _rand(m, n, seed, jnp.float64)
    got = tk.tsmm(x, bm=bm, bn=bn)
    np.testing.assert_allclose(got, ref.tsmm_ref(x), rtol=1e-9, atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(min_value=4, max_value=96),
    n=st.integers(min_value=4, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_tsmm_float32(m, n, seed):
    x = _rand(m, n, seed, jnp.float32)
    got = tk.tsmm(x)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, ref.tsmm_ref(x), rtol=1e-4, atol=1e-4)


def test_tsmm_result_symmetric():
    x = _rand(123, 37, 3, jnp.float64)
    got = np.asarray(tk.tsmm(x))
    np.testing.assert_allclose(got, got.T, rtol=0, atol=0)


def test_tsmm_zero_and_identity():
    z = jnp.zeros((16, 8), dtype=jnp.float64)
    np.testing.assert_array_equal(tk.tsmm(z), jnp.zeros((8, 8)))
    i = jnp.eye(32, dtype=jnp.float64)
    np.testing.assert_allclose(tk.tsmm(i), jnp.eye(32), atol=1e-12)


def test_vmem_footprint_model():
    # 256x128 f64 blocks: 2 inputs + 128x128 accumulator
    b = tk.vmem_footprint_bytes(256, 128)
    assert b == (2 * 256 * 128 + 128 * 128) * 8
    # must fit a 16 MiB VMEM budget
    assert b < 16 * 1024 * 1024


def test_mxu_utilization_bounds():
    u = tk.mxu_utilization_estimate(4096, 256, 256, 128)
    assert 0.0 < u <= 1.0
    # aligned shapes waste nothing beyond the symmetric skip's diagonal
    u_aligned = tk.mxu_utilization_estimate(256, 256, 128, 128)
    assert u_aligned > 0.4
