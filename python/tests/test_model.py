"""L2 correctness: the linreg model (which calls the Pallas kernel) against
the closed-form oracle, plus AOT lowering smoke tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile import aot, model  # noqa: E402
from compile.kernels import ref  # noqa: E402


def _data(m, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, n)))
    beta = jnp.asarray(rng.standard_normal((n, 1)))
    y = x @ beta
    return x, y, beta


@pytest.mark.parametrize("m,n", [(128, 16), (512, 64), (1024, 32)])
def test_linreg_matches_ref(m, n):
    x, y, _ = _data(m, n)
    got = model.linreg_ds(x, y)
    np.testing.assert_allclose(got, ref.linreg_ds_ref(x, y), rtol=1e-8, atol=1e-8)


def test_linreg_recovers_true_beta():
    x, y, beta = _data(1024, 32, seed=7)
    got = model.linreg_ds(x, y, lam=1e-9)
    np.testing.assert_allclose(got, beta, rtol=1e-6, atol=1e-6)


def test_model_ops_match_refs():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((1, 64)))
    b = jnp.asarray(rng.standard_normal((64, 16)))
    np.testing.assert_allclose(model.matmult(a, b), ref.matmult_ref(a, b), rtol=1e-12)
    s = jnp.asarray(rng.standard_normal((16, 16))) + 16 * jnp.eye(16)
    rhs = jnp.asarray(rng.standard_normal((16, 1)))
    np.testing.assert_allclose(model.solve(s, rhs), ref.solve_ref(s, rhs), rtol=1e-9)


def test_hlo_text_lowering_roundtrips():
    lowered = jax.jit(lambda x: (model.tsmm(x),)).lower(
        jax.ShapeDtypeStruct((64, 16), jnp.float64)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f64" in text


def test_shape_key_matches_rust_registry():
    assert aot.shape_key("tsmm", (4096, 256)) == "tsmm_4096x256"
    assert (
        aot.shape_key("matmult", (1, 4096), (4096, 256))
        == "matmult_1x4096_4096x256"
    )


def test_build_artifacts(tmp_path):
    # restrict to one tiny shape for speed
    old = (aot.TSMM_SHAPES, aot.MATMULT_SHAPES, aot.SOLVE_SHAPES, aot.LINREG_SHAPES)
    aot.TSMM_SHAPES = [(64, 16)]
    aot.MATMULT_SHAPES = [((1, 64), (64, 16))]
    aot.SOLVE_SHAPES = [(16, 1)]
    aot.LINREG_SHAPES = [(64, 16)]
    try:
        written = aot.build_artifacts(str(tmp_path))
    finally:
        (aot.TSMM_SHAPES, aot.MATMULT_SHAPES, aot.SOLVE_SHAPES, aot.LINREG_SHAPES) = old
    assert "tsmm_64x16" in written
    content = (tmp_path / "tsmm_64x16.hlo.txt").read_text()
    assert "HloModule" in content
