//! Bench C2 (§2 claim): "generating runtime plans from HOP DAGs is rather
//! efficient (<0.5 ms for common DAG sizes), which makes the generation
//! and costing of runtime plans feasible."
//!
//! Measures each compilation phase separately plus generation-only and
//! costing-only on the pre-compiled HOP program.

use systemds::api::{CompileOptions, Scenario};
use systemds::conf::CostConstants;
use systemds::cost;
use systemds::dml;
use systemds::ir;
use systemds::lop::SelectionHints;
use systemds::rtprog;
use systemds::util::bench::Bencher;

fn main() {
    println!("== plan_generation: per-phase latency (paper: <0.5ms/DAG) ==");
    let mut b = Bencher::new();
    for s in [Scenario::xs(), Scenario::xl1(), Scenario::xl4()] {
        let opts = CompileOptions::default();
        let args = s.args();
        let meta = s.meta(opts.cfg.blocksize);
        let script = dml::frontend(s.script()).unwrap();

        b.bench(&format!("{}: parse+validate", s.name), || {
            dml::frontend(s.script()).unwrap()
        });
        b.bench(&format!("{}: build HOPs", s.name), || {
            ir::build::build_program(&script, &args, &meta, opts.cfg.blocksize).unwrap()
        });
        // full prepared HOP program for the generation-only measurement
        let mut prog = ir::build::build_program(&script, &args, &meta, opts.cfg.blocksize).unwrap();
        ir::rewrites::rewrite_program(&mut prog);
        ir::size_prop::propagate(&mut prog, opts.cfg.blocksize);
        ir::memory::annotate(&mut prog, &opts.cfg);
        ir::exec_type::select(&mut prog, &opts.cfg, &opts.cc.0);
        let stats = b.bench(&format!("{}: generate runtime plan", s.name), || {
            rtprog::gen::generate(&prog, &opts.cfg, &opts.cc.0, &SelectionHints::default())
        });
        let med = stats.median;
        let rt = rtprog::gen::generate(&prog, &opts.cfg, &opts.cc.0, &SelectionHints::default());
        b.bench(&format!("{}: cost runtime plan", s.name), || {
            cost::cost_program(&rt, &opts.cfg, &opts.cc.0, &CostConstants::default()).total
        });
        let ok = med.as_secs_f64() < 0.5e-3;
        println!(
            "   -> {}: generation {} the paper's 0.5ms budget\n",
            s.name,
            if ok { "WITHIN" } else { "ABOVE" }
        );
    }
}
