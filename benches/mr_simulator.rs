//! MR-simulator throughput: the Figure-3 job (tsmm + r' + mapmm, two ak+)
//! at varying split counts, plus a cpmm MMCJ job — validates the simulator
//! is not the bottleneck of the end-to-end accuracy runs and exposes its
//! per-task overhead.

use std::sync::Arc;

use systemds::conf::{ClusterConfig, SystemConfig, MB};
use systemds::cp::interp::Executor;
use systemds::matrix::{DenseMatrix, Format, MatrixCharacteristics};
use systemds::rtprog::{Instr, JobType, MrInst, MrJob, MrOp};
use systemds::util::bench::Bencher;

fn mc(r: i64, c: i64) -> MatrixCharacteristics {
    MatrixCharacteristics::new(r, c, 1000, -1)
}

fn fig3_job() -> MrJob {
    MrJob {
        job_type: JobType::Gmr,
        inputs: vec!["X".into(), "ypart".into()],
        dcache: vec!["ypart".into()],
        map_insts: vec![
            MrInst { op: MrOp::Tsmm { left: true }, inputs: vec![0], output: 2, mc: mc(64, 64) },
            MrInst { op: MrOp::Transpose, inputs: vec![0], output: 3, mc: mc(64, 8192) },
            MrInst {
                op: MrOp::MapMM { right_part: true },
                inputs: vec![3, 1],
                output: 4,
                mc: mc(64, 1),
            },
        ],
        shuffle_insts: vec![],
        agg_insts: vec![
            MrInst { op: MrOp::Agg { kahan: true }, inputs: vec![2], output: 5, mc: mc(64, 64) },
            MrInst { op: MrOp::Agg { kahan: true }, inputs: vec![4], output: 6, mc: mc(64, 1) },
        ],
        other_insts: vec![],
        outputs: vec!["outA".into(), "outb".into()],
        result_indices: vec![5, 6],
        num_reducers: 4,
        replication: 1,
    }
}

fn main() {
    println!("== mr_simulator: Figure-3 job at varying split counts ==");
    let cfg = SystemConfig::default();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    let mut bench = Bencher::new();
    let x = DenseMatrix::rand(8192, 64, -1.0, 1.0, 1.0, 1);
    let y = DenseMatrix::rand(8192, 1, -1.0, 1.0, 1.0, 2);
    for block_kb in [4096.0, 512.0, 64.0] {
        let mut cc = ClusterConfig::local(threads, 2048.0 * MB);
        cc.hdfs_block_bytes = block_kb * 1024.0;
        let splits = ((8192.0 * 64.0 * 8.0) / cc.hdfs_block_bytes).ceil() as usize;
        let scratch = std::env::temp_dir().join("sysds_bench_mr");
        let stats = bench
            .bench(&format!("GMR tsmm+r'+mapmm, {splits} tasks"), || {
                let mut exec = Executor::new(&cfg, &cc, None, scratch.clone());
                exec.symbols
                    .bind_matrix("X", Arc::new(x.clone()), 1000, &mut exec.pool)
                    .unwrap();
                exec.symbols
                    .bind_matrix("ypart", Arc::new(y.clone()), 1000, &mut exec.pool)
                    .unwrap();
                for (name, m) in [("outA", mc(64, 64)), ("outb", mc(64, 1))] {
                    exec.exec_inst(&Instr::CreateVar {
                        var: name.into(),
                        path: String::new(),
                        temp: true,
                        format: Format::BinaryBlock,
                        mc: m,
                    })
                    .unwrap();
                }
                systemds::mr::simulate(&fig3_job(), &mut exec).unwrap()
            })
            .clone();
        println!(
            "   -> {:.1} µs/task",
            stats.median.as_secs_f64() * 1e6 / splits as f64
        );
    }
}
