//! Bench: the global data flow optimizer (`opt/gdf.rs`) — parallel,
//! plan-memoized enumeration of per-cut data-flow properties vs a
//! serial evaluation of the same candidate space.
//!
//! Uses the in-repo fixed-budget harness (criterion is unavailable in
//! the hermetic offline build; see rust/Cargo.toml).

use std::time::Duration;

use systemds::api::{DataScenario, GdfSpec, Scenario};
use systemds::matrix::Format;
use systemds::opt::gdf::optimize;
use systemds::util::bench::Bencher;
use systemds::util::par;

/// The full default search space (3 block sizes × 2 formats × 2
/// partition sizes × per-cut backends) on the loop-heavy CG script.
fn wide_spec(threads: usize) -> GdfSpec {
    let s = Scenario::xl1();
    let mut spec = GdfSpec::linreg_cg(DataScenario::from(&s), 20);
    spec.blocksizes = vec![500, 1000, 2000];
    spec.formats = vec![Format::BinaryBlock, Format::TextCell];
    spec.partitions_mb = vec![8.0, 32.0];
    spec.threads = threads;
    spec
}

fn main() {
    let threads = par::default_threads();
    let report = optimize(&wide_spec(threads)).expect("gdf");
    println!(
        "== GDF space: {} candidates, {} distinct plans compiled ==",
        report.candidates.len(),
        report.distinct_plans,
    );
    println!("{}", report.summary());

    let mut b = Bencher::new().with_budget(Duration::from_millis(300), Duration::from_secs(3));
    let par_stats = b
        .bench(&format!("parallel GDF ({threads} threads, memoized)"), || {
            optimize(&wide_spec(threads)).unwrap().candidates.len()
        })
        .clone();
    let ser_stats = b
        .bench("serial GDF (1 thread)", || {
            optimize(&wide_spec(1)).unwrap().candidates.len()
        })
        .clone();

    let speedup = ser_stats.median.as_secs_f64() / par_stats.median.as_secs_f64().max(1e-12);
    println!(
        "\n-> parallel GDF is {speedup:.2}x the serial evaluation ({} vs {})",
        systemds::util::bench::fmt_dur(par_stats.median),
        systemds::util::bench::fmt_dur(ser_stats.median),
    );
    if speedup > 1.0 {
        println!("-> PARALLEL WINS");
    } else {
        println!("-> parallel did not win on this machine/space");
    }

    println!("\n-- decision trace (argmin plan) --");
    print!("{}", report.decision_table());
    println!(
        "best: {} ({}) vs default {} ({:+.1}%)",
        report.best().label(),
        systemds::util::fmt::fmt_secs(report.best().cost_secs),
        systemds::util::fmt::fmt_secs(report.baseline().cost_secs),
        -report.improvement_pct()
    );
}
