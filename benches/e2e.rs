//! End-to-end throughput: compile + cost + execute the LinReg pipeline on
//! real data for both a pure-CP plan and a forced-MR plan — the workload
//! of tests/accuracy.rs as a repeatable benchmark.

use std::collections::HashMap;
use std::time::Duration;

use systemds::api::{compile, CompileOptions, LINREG_DS};
use systemds::conf::{ClusterConfig, CostConstants, MB};
use systemds::cost;
use systemds::cp::interp::Executor;
use systemds::matrix::{io, ops, DenseMatrix};
use systemds::runtime::KernelRegistry;
use systemds::util::bench::Bencher;

fn main() {
    println!("== e2e: compile + cost + execute LinReg DS ==");
    let dir = std::env::temp_dir().join("sysds_bench_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    let registry = KernelRegistry::load(std::path::Path::new("artifacts")).ok();

    let x = DenseMatrix::rand(4096, 256, -1.0, 1.0, 1.0, 1);
    let y = ops::matmult(&x, &DenseMatrix::rand(256, 1, -0.5, 0.5, 1.0, 2), threads);
    let xp = dir.join("X").to_string_lossy().to_string();
    let yp = dir.join("y").to_string_lossy().to_string();
    io::write_binary_block(&xp, &x, 1000).unwrap();
    io::write_binary_block(&yp, &y, 1000).unwrap();
    let mut args = HashMap::new();
    args.insert(1, xp);
    args.insert(2, yp);
    args.insert(3, "0".to_string());
    args.insert(4, dir.join("beta").to_string_lossy().to_string());

    let mut b = Bencher::new().with_budget(Duration::from_millis(500), Duration::from_secs(4));
    for (name, heap_mb) in [("CP plan", 2048.0), ("MR plan", 0.12)] {
        let mut cc = ClusterConfig::local(threads, heap_mb * MB);
        cc.hdfs_block_bytes = 2.0 * MB;
        let opts =
            CompileOptions { cc: systemds::api::ClusterConfigOpt(cc), ..Default::default() };
        let compiled = compile(LINREG_DS, &args, &opts).unwrap();
        let jobs = compiled.runtime.mr_job_count();
        b.bench(&format!("{name} ({jobs} MR jobs): compile"), || {
            compile(LINREG_DS, &args, &opts).unwrap()
        });
        b.bench(&format!("{name}: cost"), || {
            cost::cost_program(&compiled.runtime, &opts.cfg, &opts.cc.0, &CostConstants::default())
                .total
        });
        b.bench(&format!("{name}: execute 4096x256"), || {
            let mut exec = Executor::new(
                &opts.cfg,
                &opts.cc.0,
                registry.as_ref(),
                dir.join("scratch"),
            );
            exec.run(&compiled.runtime).unwrap()
        });
    }
}
