//! Bench T1 (Table 1): full compile + cost of every input-size scenario —
//! the optimizer-side work the paper's cost model enables. Regenerates the
//! Table-1 rows with plan characteristics and estimated cost.

use systemds::api::{CompileOptions, Scenario};
use systemds::conf::CostConstants;
use systemds::cost;
use systemds::util::bench::Bencher;

fn main() {
    println!("== table1: compile + cost per scenario (paper Table 1) ==");
    let opts = CompileOptions::default();
    let mut b = Bencher::new();
    for s in Scenario::all() {
        b.bench(&format!("compile+cost {}", s.name), || {
            let compiled = s.compile(&opts);
            cost::cost_program(&compiled.runtime, &opts.cfg, &opts.cc.0, &CostConstants::default())
                .total
        });
    }
    println!("\n-- regenerated table --");
    println!("{:<6} {:>16} {:>10} {:>8} {:>12}", "name", "X", "input", "MR jobs", "est. cost");
    for s in Scenario::all() {
        let compiled = s.compile(&opts);
        let c = cost::cost_program(
            &compiled.runtime,
            &opts.cfg,
            &opts.cc.0,
            &CostConstants::default(),
        );
        println!(
            "{:<6} {:>9}x{:<6} {:>10} {:>8} {:>11.1}s",
            s.name,
            s.x_rows,
            s.x_cols,
            systemds::util::fmt::fmt_bytes(s.input_bytes),
            compiled.runtime.mr_job_count(),
            c.total
        );
    }
}
