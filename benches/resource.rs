//! Bench R1 (tentpole): the parallel, plan-memoizing, floor-pruning
//! grid resource optimizer vs a serial, unpruned evaluation of the same
//! joint space — the paper-§1 resource-optimization consumer, scaled to
//! a heap × executor-memory × nodes × k_local × backend grid.
//!
//! Uses the in-repo fixed-budget harness (criterion is unavailable in
//! the hermetic offline build; see rust/Cargo.toml).

use std::time::Duration;

use systemds::api::{DataScenario, ResourceGrid, Scenario, LINREG_DS};
use systemds::opt::resource::optimize_grid;
use systemds::util::bench::Bencher;
use systemds::util::par;

/// A wide joint grid on the XL1 scenario: 6 heaps × 2 executor
/// memories × 2 node counts × 2 k_local values × 3 backends.
fn wide_grid(threads: usize, prune: bool) -> ResourceGrid {
    let s = Scenario::xl1();
    let mut g = ResourceGrid::new(LINREG_DS, s.args(), DataScenario::from(&s));
    g.heaps_mb = vec![256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0];
    g.threads = threads;
    g.prune = prune;
    g
}

fn main() {
    let threads = par::default_threads();
    let grid = wide_grid(threads, true);
    println!(
        "== resource grid: {} points (6 heaps x 2 exec-mems x 2 node-counts x 2 k_locals x 3 backends), {} worker threads ==",
        grid.point_count(),
        threads
    );
    let report = optimize_grid(&grid).expect("grid");
    println!("{}", report.summary());
    println!(
        "-> compile+cost invocations: {} of {} points ({} memoized, {} pruned)",
        report.distinct_plans,
        grid.point_count(),
        report.memo_hits,
        report.pruned
    );

    let mut b = Bencher::new().with_budget(Duration::from_millis(300), Duration::from_secs(3));
    let par_stats = b
        .bench(&format!("parallel grid ({threads} threads, memoized + pruned)"), || {
            optimize_grid(&wide_grid(threads, true)).unwrap().points.len()
        })
        .clone();
    let ser_stats = b
        .bench("serial grid (1 thread, no pruning)", || {
            optimize_grid(&wide_grid(1, false)).unwrap().points.len()
        })
        .clone();

    let speedup = ser_stats.median.as_secs_f64() / par_stats.median.as_secs_f64().max(1e-12);
    println!(
        "\n-> parallel+pruned grid is {speedup:.2}x the serial unpruned evaluation ({} vs {})",
        systemds::util::bench::fmt_dur(par_stats.median),
        systemds::util::bench::fmt_dur(ser_stats.median),
    );
    if speedup > 1.0 {
        println!("-> PARALLEL WINS");
    } else {
        println!("-> parallel did not win on this machine/grid");
    }

    println!("\n-- Pareto frontier --");
    print!("{}", report.frontier_table());
    println!(
        "best: {} ({})",
        report.best().label(),
        systemds::util::fmt::fmt_secs(report.best().cost_secs.unwrap_or(f64::NAN))
    );
}
