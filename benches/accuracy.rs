//! Bench: cost-model prediction accuracy before/after online calibration
//! (`repro calibrate`), reported as Q-error (`max(pred/meas, meas/pred)`).
//!
//! Modes:
//!
//! ```text
//! cargo bench --bench accuracy                   # simulated + executed
//! cargo bench --bench accuracy -- --quick        # simulated section only
//! cargo bench --bench accuracy -- --json [PATH]  # also emit BENCH_ACCURACY.json
//! ```
//!
//! The JSON report (`BENCH_ACCURACY.json` at the repository root by
//! default) is the accuracy baseline CI tracks: pre/post-calibration
//! geo-mean and p95 Q-error, the within-2x rate (the paper's §3.4
//! claim), the fitted corrections, and the re-optimization argmin flip.
//! The gated numbers come from [`MeasureMode::Simulated`] with a fixed
//! seed and a pinned 8-slot geometry, so the file is bitwise
//! machine-independent — CI regenerates it and fails on drift. The
//! executed (wall-clock) section is informational and never serialized.
//!
//! Uses a plain `main` (criterion is unavailable in the hermetic offline
//! build; see rust/Cargo.toml).

use std::path::{Path, PathBuf};

use systemds::feedback::{calibrate, CalibrateOptions, CalibrationReport, MeasureMode};

/// The gated workload: deterministic simulated measurement over the quick
/// bundled case set. Identical output on every machine and thread count.
fn simulated_report() -> CalibrationReport {
    let opts = CalibrateOptions {
        seed: 42,
        quick: true,
        mode: MeasureMode::Simulated { noise: 0.0 },
        ..Default::default()
    };
    calibrate(&opts).expect("simulated calibration")
}

fn print_report(r: &CalibrationReport) {
    println!(
        "{:<12} {:>4} {:>12} {:>12} {:>9} {:>9}",
        "class", "n", "geo-q pre", "geo-q post", "<=2x pre", "<=2x post"
    );
    for c in &r.per_class {
        println!(
            "{:<12} {:>4} {:>12.3} {:>12.3} {:>8.0}% {:>8.0}%",
            c.class.name(),
            c.before.n,
            c.before.geo_mean,
            c.after.geo_mean,
            100.0 * c.before.within_2x,
            100.0 * c.after.within_2x
        );
    }
    println!(
        "{:<12} {:>4} {:>12.3} {:>12.3} {:>8.0}% {:>8.0}%",
        "all",
        r.before.n,
        r.before.geo_mean,
        r.after.geo_mean,
        100.0 * r.before.within_2x,
        100.0 * r.after.within_2x
    );
    println!(
        "p95: {:.3} -> {:.3}; corrections: compute x{:.4} read x{:.4} write x{:.4} latency x{:.6} distributed x{:.4}",
        r.before.p95,
        r.after.p95,
        r.corrections.compute,
        r.corrections.read,
        r.corrections.write,
        r.corrections.latency,
        r.corrections.distributed
    );
    println!(
        "re-optimization ({}): argmin {} -> {}{}",
        r.reopt.scenario,
        r.reopt.argmin_before.name(),
        r.reopt.argmin_after.name(),
        if r.reopt.flipped() { "  (FLIPPED)" } else { "" }
    );
}

fn write_json(path: &Path, r: &CalibrationReport) {
    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"bench-accuracy/v1\",\n",
            "  \"generated\": \"cargo bench --bench accuracy -- --json\",\n",
            "  \"estimated\": false,\n",
            "  \"seed\": 42,\n",
            "  \"mode\": \"simulated (deterministic proxy, quick case set, 8-slot geometry)\",\n",
            "  \"records\": {records},\n",
            "  \"qerror\": {{\n",
            "    \"pre\":  {{ \"geo_mean\": {pre_geo:.6}, \"p95\": {pre_p95:.6}, \"within_2x\": {pre_2x:.4} }},\n",
            "    \"post\": {{ \"geo_mean\": {post_geo:.6}, \"p95\": {post_p95:.6}, \"within_2x\": {post_2x:.4} }}\n",
            "  }},\n",
            "  \"corrections\": {{\n",
            "    \"compute\": {c_comp:.6},\n",
            "    \"read\": {c_read:.6},\n",
            "    \"write\": {c_write:.6},\n",
            "    \"latency\": {c_lat:.8},\n",
            "    \"distributed\": {c_dist:.6}\n",
            "  }},\n",
            "  \"constants\": {{\n",
            "    \"job_latency_pre\": {jl_pre:.6},\n",
            "    \"job_latency_post\": {jl_post:.8},\n",
            "    \"flop_efficiency_post\": {fe_post:.6}\n",
            "  }},\n",
            "  \"reopt\": {{\n",
            "    \"scenario\": \"{scenario}\",\n",
            "    \"argmin_pre\": \"{argmin_pre}\",\n",
            "    \"argmin_post\": \"{argmin_post}\",\n",
            "    \"flipped\": {flipped}\n",
            "  }}\n",
            "}}\n",
        ),
        records = r.records.len(),
        pre_geo = r.before.geo_mean,
        pre_p95 = r.before.p95,
        pre_2x = r.before.within_2x,
        post_geo = r.after.geo_mean,
        post_p95 = r.after.p95,
        post_2x = r.after.within_2x,
        c_comp = r.corrections.compute,
        c_read = r.corrections.read,
        c_write = r.corrections.write,
        c_lat = r.corrections.latency,
        c_dist = r.corrections.distributed,
        jl_pre = r.initial.job_latency,
        jl_post = r.calibrated.job_latency,
        fe_post = r.calibrated.flop_efficiency,
        scenario = r.reopt.scenario,
        argmin_pre = r.reopt.argmin_before.name(),
        argmin_post = r.reopt.argmin_after.name(),
        flipped = r.reopt.flipped(),
    );
    std::fs::write(path, json).expect("write BENCH_ACCURACY.json");
    println!("wrote {}", path.display());
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args.iter().position(|a| a == "--json").map(|i| {
        match args.get(i + 1).filter(|p| !p.starts_with("--")) {
            Some(p) => PathBuf::from(p),
            None => PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_ACCURACY.json"),
        }
    });

    println!("== accuracy: simulated feedback loop (deterministic, gated) ==");
    let sim = simulated_report();
    print_report(&sim);

    if !quick {
        println!("\n== accuracy: executed feedback loop (wall-clock, informational) ==");
        match calibrate(&CalibrateOptions { quick: true, ..Default::default() }) {
            Ok(exec) => print_report(&exec),
            Err(e) => println!("executed section skipped: {e}"),
        }
    }

    if let Some(path) = json_path {
        write_json(&path, &sim);
    }
}
