//! Bench: the `repro serve` daemon at steady state — one warm process
//! answering a stream of identical `optimize` requests off the shared
//! plan memo + cost cache, against the cold baseline of paying full
//! startup + compilation per request.
//!
//! Modes:
//!
//! ```text
//! cargo bench --bench serve                  # human-readable only
//! cargo bench --bench serve -- --quick       # short measurement budget
//! cargo bench --bench serve -- --json [PATH] # also emit BENCH_SERVE.json
//! ```
//!
//! The cold side prefers a true process-per-request baseline (spawning
//! the `repro` binary with `serve` on a one-line stdin session); when
//! the binary is not built it falls back to a fresh in-process
//! [`ServeState`] per request and says so in the JSON (`cold.mode`).
//! Either way the daemon's whole value proposition is the gap: CI
//! regenerates `BENCH_SERVE.json` in `--quick` mode and fails when the
//! warm daemon is less than 5x the cold baseline, when the repeated
//! phase's cache hit rate drops below 0.5, or when the p99 latency is
//! not a finite positive number.
//!
//! Uses plain timed loops rather than `util::bench::Bencher` because the
//! per-request latency distribution (p50/p99) is itself a measured,
//! gated quantity.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::time::Instant;

use systemds::serve::{ServeOptions, ServeState};
use systemds::util::par;

/// The repeated request: backend argmin for the heaviest bundled
/// workload (LinReg CG, XL1, 20 iterations — three backend compiles
/// when cold, pure cache/memo service when warm).
const REQUEST: &str = "cmd=optimize scenario=xl1 script=cg iters=20";

fn state(threads: usize) -> ServeState {
    ServeState::new(&ServeOptions { threads, ..Default::default() })
        .expect("serve state boots")
}

/// Nearest-rank percentile over unsorted microsecond samples.
fn percentile_us(samples: &mut [u64], p: f64) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    samples.sort_unstable();
    let rank = ((p / 100.0) * samples.len() as f64).ceil() as usize;
    samples[rank.clamp(1, samples.len()) - 1]
}

struct WarmSide {
    requests: usize,
    total_secs: f64,
    rps: f64,
    p50_us: u64,
    p99_us: u64,
    hits_delta: u64,
    misses_delta: u64,
    hit_rate: f64,
}

/// Boot one daemon, absorb the cold first request, then measure the
/// repeated steady-state phase request by request.
fn measure_warm(threads: usize, requests: usize) -> WarmSide {
    let state = state(threads);
    let first = state.handle_line(REQUEST).expect("first (cold) response");
    assert!(first.contains("ok=true"), "cold request must succeed: {first}");

    let before = state.cache_stats();
    let mut lat_us: Vec<u64> = Vec::with_capacity(requests);
    let t0 = Instant::now();
    for _ in 0..requests {
        let t = Instant::now();
        let resp = state.handle_line(REQUEST).expect("warm response");
        lat_us.push(t.elapsed().as_micros().min(u128::from(u64::MAX)) as u64);
        debug_assert!(resp.contains("ok=true"), "{resp}");
    }
    let total_secs = t0.elapsed().as_secs_f64().max(1e-9);
    let after = state.cache_stats();

    let hits_delta = after.hits.saturating_sub(before.hits);
    let misses_delta = after.misses.saturating_sub(before.misses);
    let lookups = (hits_delta + misses_delta).max(1);
    WarmSide {
        requests,
        total_secs,
        rps: requests as f64 / total_secs,
        p50_us: percentile_us(&mut lat_us, 50.0),
        p99_us: percentile_us(&mut lat_us, 99.0),
        hits_delta,
        misses_delta,
        hit_rate: hits_delta as f64 / lookups as f64,
    }
}

/// Locate the built `repro` binary next to this bench executable
/// (`target/<profile>/deps/serve-*` → `target/<profile>/repro`).
fn repro_binary() -> Option<PathBuf> {
    let exe = std::env::current_exe().ok()?;
    let deps = exe.parent()?;
    for cand in [deps.join("repro"), deps.parent()?.join("repro")] {
        if cand.is_file() {
            return Some(cand);
        }
    }
    None
}

/// One full cold process: spawn `repro serve`, feed one request line on
/// stdin, read the one response line, wait for exit.
fn cold_process_request(bin: &Path) -> Result<(), String> {
    let mut child = std::process::Command::new(bin)
        .arg("serve")
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .map_err(|e| format!("spawn {}: {e}", bin.display()))?;
    child
        .stdin
        .take()
        .ok_or("child stdin")?
        .write_all(format!("{REQUEST}\n").as_bytes())
        .map_err(|e| format!("write request: {e}"))?;
    let out = child.wait_with_output().map_err(|e| format!("wait: {e}"))?;
    let resp = String::from_utf8_lossy(&out.stdout);
    if !out.status.success() || !resp.contains("ok=true") {
        return Err(format!("cold process answered: {} / {resp}", out.status));
    }
    Ok(())
}

struct ColdSide {
    mode: &'static str,
    requests: usize,
    total_secs: f64,
    rps: f64,
}

/// Cold baseline: full startup cost per request — a fresh OS process
/// when the `repro` binary is available, a fresh in-process daemon
/// state (full recompilation, empty caches) otherwise.
fn measure_cold(threads: usize, requests: usize) -> ColdSide {
    let (mode, total_secs) = match repro_binary() {
        Some(bin) => {
            let t0 = Instant::now();
            for _ in 0..requests {
                cold_process_request(&bin).expect("cold process request");
            }
            ("process", t0.elapsed().as_secs_f64().max(1e-9))
        }
        None => {
            eprintln!("(repro binary not built — cold side falls back to in-process states)");
            let t0 = Instant::now();
            for _ in 0..requests {
                let st = state(threads);
                let resp = st.handle_line(REQUEST).expect("cold response");
                assert!(resp.contains("ok=true"), "{resp}");
            }
            ("in-process", t0.elapsed().as_secs_f64().max(1e-9))
        }
    };
    ColdSide { mode, requests, total_secs, rps: requests as f64 / total_secs }
}

fn write_json(path: &Path, threads: usize, quick: bool, warm: &WarmSide, cold: &ColdSide) {
    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"bench-serve/v1\",\n",
            "  \"generated\": \"cargo bench --bench serve -- --json{quickflag}\",\n",
            "  \"workload\": {{\n",
            "    \"request\": \"{request}\",\n",
            "    \"measurement\": \"one warm daemon vs full startup cost per request\"\n",
            "  }},\n",
            "  \"threads\": {threads},\n",
            "  \"quick\": {quick},\n",
            "  \"warm\": {{\n",
            "    \"requests\": {wreq},\n",
            "    \"total_secs\": {wsecs:.6},\n",
            "    \"requests_per_sec\": {wrps:.1},\n",
            "    \"p50_us\": {p50},\n",
            "    \"p99_us\": {p99}\n",
            "  }},\n",
            "  \"cold\": {{\n",
            "    \"mode\": \"{cmode}\",\n",
            "    \"requests\": {creq},\n",
            "    \"total_secs\": {csecs:.6},\n",
            "    \"requests_per_sec\": {crps:.1}\n",
            "  }},\n",
            "  \"cache\": {{\n",
            "    \"hits\": {hits},\n",
            "    \"misses\": {misses},\n",
            "    \"hit_rate\": {hit_rate:.4}\n",
            "  }},\n",
            "  \"speedup\": {{\n",
            "    \"warm_vs_cold\": {speedup:.2}\n",
            "  }}\n",
            "}}\n",
        ),
        quickflag = if quick { " --quick" } else { "" },
        request = REQUEST,
        threads = threads,
        quick = quick,
        wreq = warm.requests,
        wsecs = warm.total_secs,
        wrps = warm.rps,
        p50 = warm.p50_us,
        p99 = warm.p99_us,
        cmode = cold.mode,
        creq = cold.requests,
        csecs = cold.total_secs,
        crps = cold.rps,
        hits = warm.hits_delta,
        misses = warm.misses_delta,
        hit_rate = warm.hit_rate,
        speedup = warm.rps / cold.rps.max(1e-9),
    );
    std::fs::write(path, json).expect("write BENCH_SERVE.json");
    println!("wrote {}", path.display());
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args.iter().position(|a| a == "--json").map(|i| {
        match args.get(i + 1).filter(|p| !p.starts_with("--")) {
            Some(p) => PathBuf::from(p),
            None => PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_SERVE.json"),
        }
    });
    let (warm_requests, cold_requests) = if quick { (200, 3) } else { (1000, 8) };

    let threads = par::default_threads();
    println!("== serve: one warm daemon vs cold startup per request, {threads} worker threads ==");

    let warm = measure_warm(threads, warm_requests);
    println!(
        "warm daemon: {} requests in {:.3}s -> {:.0} req/s (p50 {}us, p99 {}us)",
        warm.requests, warm.total_secs, warm.rps, warm.p50_us, warm.p99_us
    );
    println!(
        "steady-state cache: {} hits / {} misses ({:.1}% hit rate)",
        warm.hits_delta,
        warm.misses_delta,
        100.0 * warm.hit_rate
    );

    let cold = measure_cold(threads, cold_requests);
    println!(
        "cold {}: {} requests in {:.3}s -> {:.2} req/s",
        cold.mode, cold.requests, cold.total_secs, cold.rps
    );

    let speedup = warm.rps / cold.rps.max(1e-9);
    println!("-> warm daemon is {speedup:.1}x the cold baseline");
    if speedup >= 5.0 {
        println!("-> DAEMON WINS (>= 5x acceptance target)");
    } else {
        println!("-> below the 5x target on this machine/budget");
    }

    if let Some(path) = json_path {
        write_json(&path, threads, quick, &warm, &cold);
    }
}
