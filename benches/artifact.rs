//! Bench: warm-starting the optimizer from a persisted cost-cache
//! snapshot (`--warm-cache`) on the bundled `repro gdf` workload (LinReg
//! CG, XL1, 20 iterations, full default axis set).
//!
//! Three sides, each paying the full candidate compile (fresh evaluator
//! per run), so the deltas isolate exactly two effects:
//!
//! * **cold** — empty cost cache: every block is costed from scratch;
//! * **warm-mem** — the cache `Arc` from a prior in-process run is
//!   handed to the fresh evaluator: block costings replay from memory;
//! * **warm-disk** — the same cache, but round-tripped through the
//!   on-disk snapshot artifact: each run re-reads, checksums and decodes
//!   the file, then replays. The warm-disk / warm-mem ratio is the pure
//!   artifact overhead the CI gate bounds (≤ 1.2×).
//!
//! Modes:
//!
//! ```text
//! cargo bench --bench artifact                  # human-readable only
//! cargo bench --bench artifact -- --quick       # short measurement budget
//! cargo bench --bench artifact -- --json [PATH] # also emit BENCH_ARTIFACT.json
//! ```
//!
//! The JSON report (`BENCH_ARTIFACT.json` at the repository root by
//! default) is the warm-start perf baseline. CI regenerates it in
//! `--quick` mode and fails if the warm-from-disk run diverges from the
//! cold argmin, serves < 90% of costings from the loaded cache, or costs
//! more than 1.2× the warm-in-process run.
//!
//! Uses the in-repo fixed-budget harness (criterion is unavailable in
//! the hermetic offline build; see rust/Cargo.toml).

use std::path::{Path, PathBuf};
use std::time::Duration;

use systemds::api::{
    load_artifact, save_artifact, Artifact, CacheSnapshot, DataScenario, Evaluator, GdfSpec,
    Scenario,
};
use systemds::cost::cache::CostCache;
use systemds::opt::gdf::{optimize_with, GdfReport};
use systemds::util::bench::{fmt_dur, Bencher};
use systemds::util::par;

/// The bundled `repro gdf` workload: `repro gdf --scenario xl1 --script
/// cg --iters 20` with the default search axes.
fn gdf_workload() -> GdfSpec {
    GdfSpec::linreg_cg(DataScenario::from(&Scenario::xl1()), 20)
}

fn load_snapshot(path: &Path) -> CacheSnapshot {
    match load_artifact(path).expect("load snapshot artifact") {
        Artifact::CacheSnapshot(s) => s,
        other => panic!("expected a costcache artifact, got '{}'", other.kind()),
    }
}

struct Side {
    median_secs: f64,
    report: GdfReport,
    hit_rate: f64,
}

/// Run `make_eval() -> optimize` once per iteration, so every side pays
/// the candidate compile and only the cache source differs.
fn measure(
    b: &mut Bencher,
    name: &str,
    spec: &GdfSpec,
    mut make_eval: impl FnMut() -> Evaluator,
) -> Side {
    let stats = b
        .bench(name, || {
            let mut eval = make_eval();
            optimize_with(spec, &mut eval).unwrap().candidates.len()
        })
        .clone();
    let mut eval = make_eval();
    let report = optimize_with(spec, &mut eval).expect("stats run");
    let hit_rate = eval.run_cache_stats().hit_rate();
    Side { median_secs: stats.median.as_secs_f64().max(1e-9), report, hit_rate }
}

fn bits_match(a: &GdfReport, b: &GdfReport) -> bool {
    a.candidates.len() == b.candidates.len()
        && a.candidates
            .iter()
            .zip(&b.candidates)
            .all(|(x, y)| x.label() == y.label() && x.cost_secs.to_bits() == y.cost_secs.to_bits())
}

#[allow(clippy::too_many_arguments)]
fn write_json(
    path: &Path,
    threads: usize,
    quick: bool,
    cold: &Side,
    warm_mem: &Side,
    warm_disk: &Side,
    snapshot_entries: usize,
    snapshot_bytes: usize,
) {
    let argmin_matches = cold.report.best().label() == warm_disk.report.best().label()
        && cold.report.best().cost_secs.to_bits() == warm_disk.report.best().cost_secs.to_bits();
    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"bench-artifact/v1\",\n",
            "  \"generated\": \"cargo bench --bench artifact -- --json{quickflag}\",\n",
            "  \"workload\": {{\n",
            "    \"kind\": \"repro gdf\",\n",
            "    \"script\": \"cg\",\n",
            "    \"scenario\": \"XL1\",\n",
            "    \"iterations\": 20,\n",
            "    \"candidates\": {candidates},\n",
            "    \"measurement\": \"fresh evaluator per run; only the cache source differs\"\n",
            "  }},\n",
            "  \"threads\": {threads},\n",
            "  \"quick\": {quick},\n",
            "  \"snapshot\": {{\n",
            "    \"entries\": {entries},\n",
            "    \"bytes\": {bytes}\n",
            "  }},\n",
            "  \"wall_secs\": {{\n",
            "    \"cold_median\": {cold:.6},\n",
            "    \"warm_mem_median\": {warm_mem:.6},\n",
            "    \"warm_disk_median\": {warm_disk:.6}\n",
            "  }},\n",
            "  \"warm_disk\": {{\n",
            "    \"hit_rate\": {hit_rate:.4},\n",
            "    \"argmin_matches_cold\": {argmin},\n",
            "    \"costs_bitwise_match_cold\": {bitwise}\n",
            "  }},\n",
            "  \"ratio\": {{\n",
            "    \"warm_disk_vs_warm_mem\": {disk_ratio:.3},\n",
            "    \"cold_vs_warm_mem\": {cold_ratio:.3}\n",
            "  }}\n",
            "}}\n",
        ),
        quickflag = if quick { " --quick" } else { "" },
        candidates = cold.report.candidates.len(),
        threads = threads,
        quick = quick,
        entries = snapshot_entries,
        bytes = snapshot_bytes,
        cold = cold.median_secs,
        warm_mem = warm_mem.median_secs,
        warm_disk = warm_disk.median_secs,
        hit_rate = warm_disk.hit_rate,
        argmin = argmin_matches,
        bitwise = bits_match(&cold.report, &warm_disk.report),
        disk_ratio = warm_disk.median_secs / warm_mem.median_secs,
        cold_ratio = cold.median_secs / warm_mem.median_secs,
    );
    std::fs::write(path, json).expect("write BENCH_ARTIFACT.json");
    println!("wrote {}", path.display());
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args.iter().position(|a| a == "--json").map(|i| {
        match args.get(i + 1).filter(|p| !p.starts_with("--")) {
            Some(p) => PathBuf::from(p),
            None => PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_ARTIFACT.json"),
        }
    });
    let (warmup, budget) = if quick {
        (Duration::from_millis(100), Duration::from_millis(1200))
    } else {
        (Duration::from_millis(300), Duration::from_secs(3))
    };

    let threads = par::default_threads();
    let spec = gdf_workload();
    println!("== artifact: warm-starting `repro gdf` from a cost-cache snapshot, {threads} worker threads ==");

    // Seed run: populate a cache, snapshot it to disk once.
    let mut seed_eval = Evaluator::new(threads);
    let _ = optimize_with(&spec, &mut seed_eval).expect("seed run");
    let cache = seed_eval.cache().expect("seed evaluator keeps a cache");
    let snap = CacheSnapshot::from_cache(&cache);
    let snap_dir =
        std::env::temp_dir().join(format!("sysds_artifact_bench_{}", std::process::id()));
    std::fs::create_dir_all(&snap_dir).expect("create bench dir");
    let snap_path = snap_dir.join("gdf.costcache");
    save_artifact(&snap_path, &Artifact::CacheSnapshot(snap)).expect("save snapshot");
    let snapshot_bytes = std::fs::metadata(&snap_path).expect("stat snapshot").len() as usize;
    let snapshot_entries = load_snapshot(&snap_path).len();
    println!("snapshot: {snapshot_entries} entries, {snapshot_bytes} bytes -> {}", snap_path.display());

    let mut b = Bencher::new().with_budget(warmup, budget);
    let cold = measure(&mut b, "gdf, cold (empty cache)", &spec, || {
        Evaluator::new(threads)
    });
    let warm_mem = measure(&mut b, "gdf, warm cache from memory", &spec, || {
        Evaluator::with_cache(threads, Some(cache.clone()))
    });
    let warm_disk = measure(&mut b, "gdf, warm cache from disk", &spec, || {
        let loaded: std::sync::Arc<CostCache> = load_snapshot(&snap_path).into_cache();
        Evaluator::with_cache(threads, Some(loaded))
    });

    let disk_ratio = warm_disk.median_secs / warm_mem.median_secs;
    println!(
        "\n-> cold {} | warm-mem {} | warm-disk {} ({disk_ratio:.2}x warm-mem)",
        fmt_dur(Duration::from_secs_f64(cold.median_secs)),
        fmt_dur(Duration::from_secs_f64(warm_mem.median_secs)),
        fmt_dur(Duration::from_secs_f64(warm_disk.median_secs)),
    );
    println!(
        "warm-from-disk: {:.1}% hit rate, argmin {} cold, costs {} cold",
        100.0 * warm_disk.hit_rate,
        if cold.report.best().label() == warm_disk.report.best().label() { "matches" } else { "DIVERGES from" },
        if bits_match(&cold.report, &warm_disk.report) { "bitwise match" } else { "DIVERGE from" },
    );
    if disk_ratio <= 1.2 {
        println!("-> ARTIFACT OVERHEAD OK (<= 1.2x warm-in-process acceptance target)");
    } else {
        println!("-> artifact overhead above the 1.2x target on this machine/budget");
    }

    if let Some(path) = json_path {
        write_json(
            &path,
            threads,
            quick,
            &cold,
            &warm_mem,
            &warm_disk,
            snapshot_entries,
            snapshot_bytes,
        );
    }
    let _ = std::fs::remove_dir_all(&snap_dir);
}
