//! Bench A2 (Eq. 2): the white-box FLOP models across the
//! dense/sparse regimes, plus estimator throughput per instruction —
//! the cost model must stay cheap enough to be called inside optimizer
//! search loops (resource optimization recompiles per configuration).

use systemds::api::{CompileOptions, Scenario};
use systemds::conf::CostConstants;
use systemds::cost::{self, flops};
use systemds::matrix::MatrixCharacteristics;
use systemds::util::bench::Bencher;

fn main() {
    println!("== op_costs: Eq. 2 cost functions (tsmm dense/sparse sweep) ==");
    let clock = 2.15e9;
    println!("{:>10} {:>14} {:>12}", "sparsity", "FLOPs", "est. time");
    for s in [1.0, 0.5, 0.39, 0.1, 0.01, 0.001] {
        let mut mc = MatrixCharacteristics::dense(100_000_000, 1_000, 1000);
        mc.nnz = (mc.rows as f64 * mc.cols as f64 * s) as i64;
        let f = flops::tsmm(&mc);
        println!("{:>10} {:>14.3e} {:>11.2}s", s, f, f / clock / 72.0);
    }

    println!("\n== estimator micro-benchmarks ==");
    let mut b = Bencher::new();
    b.bench("flops::tsmm", || {
        flops::tsmm(&MatrixCharacteristics::dense(100_000_000, 1_000, 1000))
    });
    b.bench("flops::matmult", || {
        flops::matmult(
            &MatrixCharacteristics::dense(1_000, 100_000_000, 1000),
            &MatrixCharacteristics::dense(100_000_000, 1, 1000),
        )
    });
    b.bench("flops::solve", || {
        flops::solve(
            &MatrixCharacteristics::dense(1_000, 1_000, 1000),
            &MatrixCharacteristics::dense(1_000, 1, 1000),
        )
    });

    // whole-plan costing throughput (instructions/second)
    let opts = CompileOptions::default();
    for s in [Scenario::xs(), Scenario::xl1()] {
        let compiled = s.compile(&opts);
        let (cp, mr) = compiled.runtime.size();
        let stats = b.bench(&format!("cost_program {} ({cp} CP/{mr} MR)", s.name), || {
            cost::cost_program(&compiled.runtime, &opts.cfg, &opts.cc.0, &CostConstants::default())
                .total
        });
        let per_inst = stats.median.as_secs_f64() / (cp + mr) as f64;
        println!("   -> {:.1} ns/instruction", per_inst * 1e9);
    }
}
