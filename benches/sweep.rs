//! Bench S1 (tentpole): the parallel, plan-memoizing scenario sweep vs a
//! serial loop of `api::compile` + `cost` calls on the same grid — the
//! consumer pattern the paper's cost model exists for (many plan/config
//! combinations costed cheaply and compared).
//!
//! Uses the in-repo fixed-budget harness (criterion is unavailable in
//! the hermetic offline build; see rust/Cargo.toml).

use std::time::Duration;

use systemds::api::{DataScenario, Scenario, SweepSpec};
use systemds::opt::sweep::{heap_clock_clusters, sweep, sweep_serial};
use systemds::util::bench::Bencher;
use systemds::util::par;

/// A wide grid: 5 Table-1 scenarios × (7 heap sizes × 2 clock variants)
/// = 70 cells, 35 distinct plan shapes.
fn wide_spec(threads: usize) -> SweepSpec {
    let mut spec = SweepSpec::linreg_default();
    spec.clusters =
        heap_clock_clusters(&[256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0, 16384.0]);
    spec.scenarios = Scenario::all().iter().map(DataScenario::from).collect();
    spec.threads = threads;
    spec
}

fn main() {
    let threads = par::default_threads();
    let spec = wide_spec(threads);
    println!(
        "== sweep: {} cells ({} clusters x {} scenarios), {} worker threads ==",
        spec.cell_count(),
        spec.clusters.len(),
        spec.scenarios.len(),
        threads
    );
    let report = sweep(&spec).expect("sweep");
    println!("{}", report.summary());

    let mut b = Bencher::new().with_budget(Duration::from_millis(300), Duration::from_secs(3));
    let par_stats = b
        .bench(&format!("parallel sweep ({threads} threads, memoized)"), || {
            sweep(&spec).unwrap().cells.len()
        })
        .clone();
    let ser_stats = b
        .bench("serial compile+cost loop (no memoization)", || {
            sweep_serial(&spec).unwrap().cells.len()
        })
        .clone();

    let speedup = ser_stats.median.as_secs_f64() / par_stats.median.as_secs_f64().max(1e-12);
    println!(
        "\n-> parallel sweep is {speedup:.2}x the serial loop ({} vs {})",
        systemds::util::bench::fmt_dur(par_stats.median),
        systemds::util::bench::fmt_dur(ser_stats.median),
    );
    if speedup > 1.0 {
        println!("-> PARALLEL WINS");
    } else {
        println!("-> parallel did not win on this machine/grid");
    }

    println!("\n-- ranked table (top 10) --");
    for line in report.table().lines().take(12) {
        println!("{line}");
    }
}
