//! CP hot-path kernels: native Rust (single/multi-threaded) vs the
//! AOT-compiled PJRT artifacts — the L3/L1 performance surface of the
//! §Perf pass. Run `make artifacts` first to include the PJRT rows.

use systemds::matrix::{ops, DenseMatrix};
use systemds::runtime::{kernel_key, KernelRegistry};
use systemds::util::bench::Bencher;

fn main() {
    println!("== cp_ops: tsmm / matmult / solve kernels ==");
    let registry = KernelRegistry::load(std::path::Path::new("artifacts")).ok();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    let mut b = Bencher::new();

    for (m, n) in [(2048usize, 128usize), (4096, 256)] {
        let x = DenseMatrix::rand(m, n, -1.0, 1.0, 1.0, 1);
        let flops = 0.5 * m as f64 * (n * n) as f64;
        let s1 = b.bench(&format!("tsmm {m}x{n} native 1t"), || ops::tsmm_left(&x, 1)).clone();
        let st = b
            .bench(&format!("tsmm {m}x{n} native {threads}t"), || ops::tsmm_left(&x, threads))
            .clone();
        println!(
            "   -> native {:.2} GFLOP/s (1t), {:.2} GFLOP/s ({threads}t)",
            flops / s1.median.as_secs_f64() / 1e9,
            flops / st.median.as_secs_f64() / 1e9
        );
        if let Some(reg) = &registry {
            let key = kernel_key("tsmm", &[(m, n)]);
            if reg.has(&key) {
                // warm-compile before measuring
                let _ = reg.execute(&key, &[&x]);
                let sp =
                    b.bench(&format!("tsmm {m}x{n} PJRT"), || reg.execute(&key, &[&x])).clone();
                println!("   -> PJRT {:.2} GFLOP/s", flops / sp.median.as_secs_f64() / 1e9);
            }
        }
    }

    // matvec (the (y'X)' rewrite path) + solve
    let x = DenseMatrix::rand(4096, 256, -1.0, 1.0, 1.0, 2);
    let yt = DenseMatrix::rand(1, 4096, -1.0, 1.0, 1.0, 3);
    b.bench("matmult 1x4096 * 4096x256 native", || ops::matmult(&yt, &x, threads));
    if let Some(reg) = &registry {
        let key = kernel_key("matmult", &[(1, 4096), (4096, 256)]);
        if reg.has(&key) {
            let _ = reg.execute(&key, &[&yt, &x]);
            b.bench("matmult 1x4096 * 4096x256 PJRT", || reg.execute(&key, &[&yt, &x]));
        }
    }
    let a = {
        let mut a = ops::tsmm_left(&DenseMatrix::rand(512, 256, -1.0, 1.0, 1.0, 4), threads);
        for i in 0..256 {
            a.values[i * 256 + i] += 1.0;
        }
        a
    };
    let rhs = DenseMatrix::rand(256, 1, -1.0, 1.0, 1.0, 5);
    b.bench("solve 256 native", || ops::solve(&a, &rhs).unwrap());
    if let Some(reg) = &registry {
        let key = kernel_key("solve", &[(256, 256), (256, 1)]);
        if reg.has(&key) {
            let _ = reg.execute(&key, &[&a, &rhs]);
            b.bench("solve 256 PJRT", || reg.execute(&key, &[&a, &rhs]));
        }
    }

    b.bench("transpose 4096x256", || ops::transpose(&x));
}
