//! Bench: the incremental plan-costing engine on the bundled `repro gdf`
//! workload (LinReg CG, XL1, 20 iterations, full default axis set) —
//! block-level cost caching ON vs OFF, parallel vs serial, measured at
//! steady state (compile memo warm on both sides, so the delta is the
//! costing engine, not compilation).
//!
//! Modes:
//!
//! ```text
//! cargo bench --bench costcache                  # human-readable only
//! cargo bench --bench costcache -- --quick       # short measurement budget
//! cargo bench --bench costcache -- --json [PATH] # also emit BENCH_COSTCACHE.json
//! ```
//!
//! The JSON report (`BENCH_COSTCACHE.json` at the repository root by
//! default) is the perf baseline this and future PRs track: candidate
//! evaluations per second, cache hit rate, serial-vs-parallel and
//! cached-vs-uncached speedups. CI regenerates it in `--quick` mode,
//! validates the schema and fails if cached evaluation is slower than
//! uncached.
//!
//! Uses the in-repo fixed-budget harness (criterion is unavailable in
//! the hermetic offline build; see rust/Cargo.toml).

use std::path::{Path, PathBuf};
use std::time::Duration;

use systemds::api::{CacheStats, DataScenario, Evaluator, GdfSpec, Scenario};
use systemds::opt::gdf::{optimize_with, GdfReport};
use systemds::util::bench::{fmt_dur, Bencher};
use systemds::util::par;

/// The bundled `repro gdf` workload: `repro gdf --scenario xl1 --script
/// cg --iters 20` with the default search axes (3 block sizes × 2
/// formats × 2 partition sizes × per-cut backend assignments).
fn gdf_workload() -> GdfSpec {
    GdfSpec::linreg_cg(DataScenario::from(&Scenario::xl1()), 20)
}

struct Side {
    median_secs: f64,
    report: GdfReport,
}

/// Warm an evaluator on the workload (compiles everything once), then
/// measure repeated re-optimization — the steady state where only the
/// costing engine runs — and capture one post-measurement report for
/// the per-run cache statistics.
fn measure(b: &mut Bencher, name: &str, spec: &GdfSpec, eval: &mut Evaluator) -> Side {
    let _ = optimize_with(spec, eval).expect("warm-up run");
    let stats = b.bench(name, || optimize_with(spec, eval).unwrap().candidates.len()).clone();
    let report = optimize_with(spec, eval).expect("stats run");
    Side { median_secs: stats.median.as_secs_f64().max(1e-9), report }
}

fn write_json(path: &Path, threads: usize, quick: bool, cached: &Side, uncached: &Side, serial: &Side) {
    let candidates = cached.report.candidates.len();
    let cr = &cached.report;
    let hit_rate = CacheStats {
        hits: cr.cache_hits,
        misses: cr.cache_misses,
        ..CacheStats::default()
    }
    .hit_rate();
    let json = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"bench-costcache/v1\",\n",
            "  \"generated\": \"cargo bench --bench costcache -- --json{quickflag}\",\n",
            "  \"workload\": {{\n",
            "    \"kind\": \"{kind}\",\n",
            "    \"script\": \"cg\",\n",
            "    \"scenario\": \"XL1\",\n",
            "    \"iterations\": 20,\n",
            "    \"candidates\": {candidates},\n",
            "    \"measurement\": \"steady-state re-optimization, compile memo warm on both sides\"\n",
            "  }},\n",
            "  \"threads\": {threads},\n",
            "  \"quick\": {quick},\n",
            "  \"wall_secs\": {{\n",
            "    \"cached_median\": {cached:.6},\n",
            "    \"uncached_median\": {uncached:.6},\n",
            "    \"serial_median\": {serial:.6},\n",
            "    \"parallel_median\": {cached:.6}\n",
            "  }},\n",
            "  \"cells_per_sec\": {{\n",
            "    \"cached\": {cps_cached:.1},\n",
            "    \"uncached\": {cps_uncached:.1}\n",
            "  }},\n",
            "  \"cache\": {{\n",
            "    \"hits\": {hits},\n",
            "    \"misses\": {misses},\n",
            "    \"hit_rate\": {hit_rate:.4},\n",
            "    \"skipped_duplicate_candidates\": {skipped}\n",
            "  }},\n",
            "  \"speedup\": {{\n",
            "    \"cached_vs_uncached\": {speedup:.2},\n",
            "    \"parallel_vs_serial\": {par_speedup:.2}\n",
            "  }},\n",
            "  \"plan_memo\": {{\n",
            "    \"distinct_plans\": {distinct},\n",
            "    \"candidates\": {candidates}\n",
            "  }}\n",
            "}}\n",
        ),
        quickflag = if quick { " --quick" } else { "" },
        kind = "repro gdf",
        candidates = candidates,
        threads = threads,
        quick = quick,
        cached = cached.median_secs,
        uncached = uncached.median_secs,
        serial = serial.median_secs,
        cps_cached = candidates as f64 / cached.median_secs,
        cps_uncached = candidates as f64 / uncached.median_secs,
        hits = cr.cache_hits,
        misses = cr.cache_misses,
        hit_rate = hit_rate,
        skipped = cr.skipped_duplicates,
        speedup = uncached.median_secs / cached.median_secs,
        par_speedup = serial.median_secs / cached.median_secs,
        distinct = cr.distinct_plans,
    );
    std::fs::write(path, json).expect("write BENCH_COSTCACHE.json");
    println!("wrote {}", path.display());
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args.iter().position(|a| a == "--json").map(|i| {
        match args.get(i + 1).filter(|p| !p.starts_with("--")) {
            Some(p) => PathBuf::from(p),
            None => PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_COSTCACHE.json"),
        }
    });
    let (warmup, budget) = if quick {
        (Duration::from_millis(100), Duration::from_millis(1200))
    } else {
        (Duration::from_millis(300), Duration::from_secs(3))
    };

    let threads = par::default_threads();
    let spec = gdf_workload();
    println!(
        "== costcache: the `repro gdf` workload at steady state, {threads} worker threads =="
    );

    let mut b = Bencher::new().with_budget(warmup, budget);
    let mut cached_eval = Evaluator::new(threads);
    let cached = measure(&mut b, "gdf costing, block cache ON", &spec, &mut cached_eval);
    let mut uncached_eval = Evaluator::without_cost_cache(threads);
    let uncached = measure(&mut b, "gdf costing, block cache OFF", &spec, &mut uncached_eval);
    let mut serial_eval = Evaluator::new(1);
    let serial = measure(&mut b, "gdf costing, cache ON, 1 thread", &spec, &mut serial_eval);

    let speedup = uncached.median_secs / cached.median_secs;
    let par_speedup = serial.median_secs / cached.median_secs;
    let cr = &cached.report;
    println!(
        "\nworkload: {} candidates, {} distinct plans, {} duplicate costings skipped",
        cr.candidates.len(),
        cr.distinct_plans,
        cr.skipped_duplicates
    );
    let hit_rate = CacheStats {
        hits: cr.cache_hits,
        misses: cr.cache_misses,
        ..CacheStats::default()
    }
    .hit_rate();
    println!(
        "steady-state cache: {} hits / {} misses per run ({:.1}% hit rate)",
        cr.cache_hits,
        cr.cache_misses,
        100.0 * hit_rate
    );
    println!(
        "-> cached is {speedup:.2}x uncached ({} vs {}); parallel is {par_speedup:.2}x serial",
        fmt_dur(Duration::from_secs_f64(cached.median_secs)),
        fmt_dur(Duration::from_secs_f64(uncached.median_secs)),
    );
    if speedup >= 3.0 {
        println!("-> CACHE WINS (>= 3x acceptance target)");
    } else if speedup >= 1.0 {
        println!("-> cache wins, below the 3x target on this machine/budget");
    } else {
        println!("-> cache LOST on this machine/budget");
    }

    if let Some(path) = json_path {
        write_json(&path, threads, quick, &cached, &uncached, &serial);
    }
}
