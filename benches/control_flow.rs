//! Bench A1 (Eq. 1): cost aggregation over deep control-flow structures —
//! nested for/parfor/while/if and function calls — both the estimator's
//! correct weighting (printed) and its latency on deep programs.

use std::collections::HashMap;

use systemds::api::{compile_with_meta, CompileOptions};
use systemds::conf::CostConstants;
use systemds::cost;
use systemds::ir::build::StaticMeta;
use systemds::matrix::{Format, MatrixCharacteristics};
use systemds::util::bench::Bencher;

fn meta() -> StaticMeta {
    StaticMeta::default().with(
        "data/X",
        MatrixCharacteristics::dense(10_000, 1_000, 1000),
        Format::BinaryBlock,
    )
}

fn args() -> HashMap<usize, String> {
    let mut m = HashMap::new();
    m.insert(1, "data/X".to_string());
    m.insert(4, "data/out".to_string());
    m
}

fn cost_of(src: &str) -> f64 {
    let opts = CompileOptions::default();
    let c = compile_with_meta(src, &args(), &meta(), &opts).unwrap();
    cost::cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &CostConstants::default()).total
}

fn main() {
    println!("== control_flow: Eq. 1 weights ==");
    let body = "s = s + sum(X);";
    let base = cost_of(&format!("X = read($1);\ns = 0;\n{body}\nwrite(s, $4);"));
    let for10 = cost_of(&format!(
        "X = read($1);\ns = 0;\nfor (i in 1:10) {{ {body} }}\nwrite(s, $4);"
    ));
    let parfor24 = cost_of(&format!(
        "X = read($1);\ns = 0;\nparfor (i in 1:24) {{ {body} }}\nwrite(s, $4);"
    ));
    let while_loop = cost_of(&format!(
        "X = read($1);\ns = 0;\nwhile (s < 100) {{ {body} }}\nwrite(s, $4);"
    ));
    let branch = cost_of(&format!(
        "X = read($1);\ns = 0;\nc = sum(X);\nif (c > 0) {{ {body} }} else {{ s = 1; }}\nwrite(s, $4);"
    ));
    println!("single body:          {base:.4}s");
    println!("for 1:10 (w=N):       {for10:.4}s");
    println!("parfor 1:24 (w=⌈N/k⌉): {parfor24:.4}s");
    println!("while (w=N̂=10):       {while_loop:.4}s");
    println!("if (w=1/2):           {branch:.4}s");

    println!("\n== deep-nesting estimator latency ==");
    let mut b = Bencher::new();
    for depth in [2usize, 4, 6] {
        let mut src = String::from("X = read($1);\ns = 0;\n");
        for d in 0..depth {
            src.push_str(&format!("for (i{d} in 1:5) {{\n"));
        }
        src.push_str("s = s + sum(X);\n");
        for _ in 0..depth {
            src.push_str("}\n");
        }
        src.push_str("write(s, $4);");
        let opts = CompileOptions::default();
        let c = compile_with_meta(&src, &args(), &meta(), &opts).unwrap();
        b.bench(&format!("cost nested-for depth {depth}"), || {
            cost::cost_program(&c.runtime, &opts.cfg, &opts.cc.0, &CostConstants::default())
                .total
        });
    }
}
