//! **End-to-end validation driver** (paper §3.4): generate real data,
//! compile runtime plans, *estimate* their cost with the white-box model,
//! then *actually execute* them on the hybrid CP/MR runtime (PJRT kernels
//! on the hot path) and compare.
//!
//! The paper's headline accuracy claim: "in both examples, the estimated
//! costs were within 2x of the actual execution time".
//!
//! Like the paper's per-cluster constants (150 MB/s HDFS, 2.15 GHz
//! effective clock), the local [`CostConstants`] are calibrated once with
//! two micro-probes (one kernel timing, one file read) — no profiling of
//! the workload itself (R1: analytical model).
//!
//! ```sh
//! make artifacts && cargo run --release --example cost_accuracy
//! ```

use std::collections::HashMap;
use std::time::Instant;

use systemds::api::{compile, CompileOptions, LINREG_DS};
use systemds::conf::{ClusterConfig, CostConstants, MB};
use systemds::cost;
use systemds::cp::interp::Executor;
use systemds::matrix::{io, ops, DenseMatrix};
use systemds::runtime::KernelRegistry;
use systemds::util::error::{Error, Result};

struct Case {
    name: &'static str,
    rows: usize,
    cols: usize,
    heap_mb: f64,
    script: &'static str,
}

/// A loop workload exercising the Eq.-1 control-flow aggregation.
const LOOP_SCRIPT: &str = r#"X = read($1);
y = read($2);
s = 0;
for (i in 1:10) {
  s = s + sum(X);
}
b = t(X) %*% y;
r = sum(b) + s;
write(r, $4);"#;

fn main() -> Result<()> {
    let dir = std::env::temp_dir().join("sysds_cost_accuracy");
    std::fs::create_dir_all(&dir)?;
    let registry = KernelRegistry::load(std::path::Path::new("artifacts")).ok();
    let registry = registry.filter(|r| !r.is_empty());
    if registry.is_none() {
        eprintln!("note: artifacts/ missing — falling back to native kernels (run `make artifacts`)");
    }
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);

    // ---- calibrate local cost constants (two micro-probes) ----
    let k = calibrate(&dir, registry.as_ref(), threads)?;
    eprintln!(
        "calibrated: clock {:.2e} flops/s, read bw {:.0} MiB/s, write bw {:.0} MiB/s",
        k.0, k.1.hdfs_read_binaryblock / MB, k.1.hdfs_write_binaryblock / MB
    );
    let (clock, consts) = k;

    let cases = [
        Case { name: "linreg CP 2048x128", rows: 2048, cols: 128, heap_mb: 2048.0, script: LINREG_DS },
        Case { name: "linreg CP 4096x256", rows: 4096, cols: 256, heap_mb: 2048.0, script: LINREG_DS },
        Case { name: "linreg MR 8192x256", rows: 8192, cols: 256, heap_mb: 0.12, script: LINREG_DS },
        Case { name: "loop    CP 2048x128", rows: 2048, cols: 128, heap_mb: 2048.0, script: LOOP_SCRIPT },
    ];

    println!(
        "{:<22} {:>8} {:>12} {:>12} {:>8}",
        "case", "MR jobs", "estimated", "actual", "ratio"
    );
    println!("{}", "-".repeat(68));
    let mut worst: f64 = 1.0;
    for case in &cases {
        let (est, actual, mr_jobs) =
            run_case(case, &dir, registry.as_ref(), threads, clock, &consts)?;
        let ratio = if actual > 0.0 { est / actual } else { f64::NAN };
        worst = worst.max(ratio.max(1.0 / ratio));
        println!(
            "{:<22} {:>8} {:>11.3}s {:>11.3}s {:>8.2}",
            case.name, mr_jobs, est, actual, ratio
        );
    }
    println!("{}", "-".repeat(68));
    println!(
        "worst-case estimate/actual discrepancy: {worst:.2}x (paper claim: within 2x)"
    );
    Ok(())
}

/// Calibrate (clock_hz, constants) from one tsmm probe + one IO probe.
fn calibrate(
    dir: &std::path::Path,
    registry: Option<&KernelRegistry>,
    threads: usize,
) -> Result<(f64, CostConstants)> {
    // compute probe: tsmm on 2048x128; the executor's adaptive dispatch
    // picks the faster of PJRT and native, so calibrate against that same
    // minimum.
    let x = DenseMatrix::rand(2048, 128, -1.0, 1.0, 1.0, 3);
    let flops = 0.5 * 2048.0 * 128.0 * 128.0;
    let reps = 5;
    let time_of = |f: &dyn Fn() -> DenseMatrix| -> f64 {
        std::hint::black_box(f()); // warm
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(f());
        }
        t0.elapsed().as_secs_f64() / reps as f64
    };
    let t_native = time_of(&|| ops::tsmm_left(&x, threads));
    let t_pjrt = registry
        .and_then(|reg| {
            reg.has("tsmm_2048x128").then(|| {
                time_of(&|| reg.execute("tsmm_2048x128", &[&x]).unwrap().unwrap())
            })
        })
        .unwrap_or(f64::INFINITY);
    let clock = flops / t_native.min(t_pjrt);

    // IO probe: write + read an 8 MiB file
    let m = DenseMatrix::rand(1024, 1024, 0.0, 1.0, 1.0, 4);
    let path = dir.join("io_probe").to_string_lossy().to_string();
    let t0 = Instant::now();
    io::write_binary_block(&path, &m, 1024)?;
    let write_bw = 8.0 * 1024.0 * 1024.0 / t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let _ = io::read_binary_block(&path)?;
    let read_bw = 8.0 * 1024.0 * 1024.0 / t0.elapsed().as_secs_f64();

    let consts = CostConstants {
        hdfs_read_binaryblock: read_bw,
        hdfs_read_text: read_bw / 2.0,
        hdfs_write_binaryblock: write_bw,
        hdfs_write_text: write_bw / 2.0,
        local_read: read_bw,
        local_write: write_bw,
        dcache_read: read_bw,
        shuffle_bw: write_bw,
        // the simulator has no JVM startup: latency is thread-spawn scale
        job_latency: 2e-3,
        task_latency: 2e-5,
        dop_scale: 1.0,
        ..CostConstants::default()
    };
    Ok((clock, consts))
}

fn run_case(
    case: &Case,
    dir: &std::path::Path,
    registry: Option<&KernelRegistry>,
    threads: usize,
    clock: f64,
    consts: &CostConstants,
) -> Result<(f64, f64, usize)> {
    let tag = format!("{}x{}_{}", case.rows, case.cols, case.heap_mb);
    let x = DenseMatrix::rand(case.rows, case.cols, -1.0, 1.0, 1.0, 42);
    let beta = DenseMatrix::rand(case.cols, 1, -0.5, 0.5, 1.0, 43);
    let y = ops::matmult(&x, &beta, threads);
    let xp = dir.join(format!("X_{tag}")).to_string_lossy().to_string();
    let yp = dir.join(format!("y_{tag}")).to_string_lossy().to_string();
    io::write_binary_block(&xp, &x, 1000)?;
    io::write_binary_block(&yp, &y, 1000)?;
    let mut args = HashMap::new();
    args.insert(1, xp);
    args.insert(2, yp);
    args.insert(3, "0".to_string());
    args.insert(4, dir.join(format!("out_{tag}")).to_string_lossy().to_string());

    // local cluster: heap controls CP-vs-MR plan shape
    let mut cc = ClusterConfig::local(threads, case.heap_mb * MB);
    cc.clock_hz = clock / threads as f64; // per-"slot" rate; k_eff re-scales
    cc.hdfs_block_bytes = 2.0 * MB;
    // single-node simulator: all map slots are the local threads
    cc.k_map = threads;
    cc.k_reduce = threads;
    let opts = CompileOptions {
        cc: systemds::api::ClusterConfigOpt(cc.clone()),
        ..Default::default()
    };
    // CP compute in the estimator is single-threaded flops; our executor
    // uses all threads (or PJRT). Calibration folds that into clock_hz:
    // clock was measured end-to-end, so CP estimates divide by 1.
    let mut est_cc = cc.clone();
    est_cc.clock_hz = clock;

    let compiled = compile(case.script, &args, &opts).map_err(Error::msg)?;
    let report = cost::cost_program(&compiled.runtime, &opts.cfg, &est_cc, consts);

    // Warm run first: lazy PJRT kernel compilation happens once per process
    // (the paper's actuals are steady-state cluster runs), then measure the
    // best of three warm executions.
    let mut exec = Executor::new(&opts.cfg, &cc, registry, dir.join(format!("scratch_{tag}")));
    exec.run(&compiled.runtime)?;
    let mut actual = f64::INFINITY;
    for _ in 0..3 {
        let mut exec =
            Executor::new(&opts.cfg, &cc, registry, dir.join(format!("scratch_{tag}")));
        let t0 = Instant::now();
        exec.run(&compiled.runtime)?;
        actual = actual.min(t0.elapsed().as_secs_f64());
    }
    Ok((report.total, actual, compiled.runtime.mr_job_count()))
}
