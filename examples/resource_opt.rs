//! Resource-optimization use case (paper §1): sweep memory budgets,
//! recompile + cost the generated plans under each, and report the
//! cost-vs-resources trade-off. Plan shape flips (MR → hybrid → CP) as
//! the budget crosses operator memory estimates — the reason a
//! plan-level analytical cost model is required.
//!
//! Shows both the legacy single-axis heap sweep and the joint grid
//! optimizer with its (budget, time) Pareto frontier.
//!
//! ```sh
//! cargo run --release --example resource_opt
//! ```

use systemds::api::{optimize_resources, DataScenario, ResourceGrid, Scenario};
use systemds::conf::{ClusterConfig, MB};
use systemds::opt::{compare, resource};

fn main() {
    let heaps = [256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0, 16384.0];
    for s in [Scenario::xs(), Scenario::xl1()] {
        println!("=== scenario {} ({}x{}) ===", s.name, s.x_rows, s.x_cols);
        let choice = resource::optimize(
            s.script(),
            &s.args(),
            &s.meta(1000),
            &ClusterConfig::paper_cluster(),
            &heaps,
        )
        .expect("sweep");
        println!("{:>10} {:>8} {:>14}", "heap", "MR jobs", "est. cost");
        for p in &choice.points {
            let marker = if p.heap_bytes == choice.best.heap_bytes { "  <= best" } else { "" };
            println!(
                "{:>8}MB {:>8} {:>13.1}s{marker}",
                (p.heap_bytes / MB) as i64,
                p.mr_jobs,
                p.cost_secs
            );
        }
        println!();
    }

    // the joint grid: heap x executor-memory x nodes x k_local x backend,
    // memoized + pruned, reported as a Pareto frontier
    println!("=== grid resource optimizer, scenario XL1 (joint axes) ===");
    let s = Scenario::xl1();
    let grid = ResourceGrid::new(s.script(), s.args(), DataScenario::from(&s));
    let report = optimize_resources(&grid).expect("grid");
    print!("{}", report.frontier_table());
    println!(
        "best: {} ({:.1}s)\n{}",
        report.best().label(),
        report.best().cost_secs.unwrap_or(f64::NAN),
        report.summary()
    );
    println!();

    // global plan comparison: what would forcing each physical operator cost?
    println!("=== plan alternatives, scenario XL1 (ablation of §2 choices) ===");
    let alts = compare::compare_plans(
        s.script(),
        &s.args(),
        &s.meta(1000),
        &Default::default(),
    )
    .expect("compare");
    println!("{:<24} {:>8} {:>14}", "variant", "MR jobs", "est. cost");
    for a in &alts {
        println!("{:<24} {:>8} {:>13.1}s", a.name, a.mr_jobs, a.cost_secs);
    }
}
