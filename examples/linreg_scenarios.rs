//! Table 1 + §2 reproduction: compile all five input-size scenarios and
//! report the generated plan characteristics the paper discusses —
//! operator selection (tsmm / mapmm / cpmm), the (yᵀX)ᵀ rewrite, broadcast
//! partitioning, and the piggybacked MR-job counts (XL1 = 1, XL2–XL4 = 3).
//!
//! ```sh
//! cargo run --release --example linreg_scenarios
//! ```

use systemds::api::{CompileOptions, Scenario};
use systemds::conf::CostConstants;
use systemds::cost;
use systemds::util::fmt::fmt_bytes;

fn main() {
    let opts = CompileOptions::default();
    println!(
        "{:<6} {:>16} {:>9} | {:>7} {:>22} {:>10} {:>11}",
        "name", "X dims", "size", "MR jobs", "X'X / X'y operators", "partition", "est. cost"
    );
    println!("{}", "-".repeat(92));
    for s in Scenario::all() {
        let compiled = s.compile(&opts);
        let plan = compiled.explain_runtime();
        let mr_jobs = compiled.runtime.mr_job_count();
        let xtx = if plan.contains("cpmm") && s.x_cols > 1000 {
            "cpmm"
        } else if mr_jobs > 0 && plan.contains("MR tsmm") {
            "MR tsmm"
        } else {
            "CP tsmm"
        };
        let xty = if plan.contains("mapmm") {
            "mapmm"
        } else if mr_jobs > 0 && plan.matches("cpmm").count() >= 1 && !plan.contains("mapmm") {
            "cpmm"
        } else {
            "CP (y'X)'"
        };
        let partition = plan.contains("CP partition");
        let report = cost::cost_program(
            &compiled.runtime,
            &opts.cfg,
            &opts.cc.0,
            &CostConstants::default(),
        );
        println!(
            "{:<6} {:>9}x{:<6} {:>9} | {:>7} {:>11} / {:<8} {:>10} {:>10.1}s",
            s.name,
            s.x_rows,
            s.x_cols,
            fmt_bytes(s.input_bytes),
            mr_jobs,
            xtx,
            xty,
            if partition { "yes" } else { "no" },
            report.total,
        );
    }
    println!();
    println!("paper §2 expectations: XS all-CP; XL1 one GMR job (tsmm+r'+mapmm");
    println!("piggybacked, partitioned broadcast of y); XL2 cpmm for X'X (wide rows);");
    println!("XL3 cpmm for X'y (broadcast exceeds map budget); XL4 both cpmm —");
    println!("each of XL2-XL4 compiling to exactly three MR jobs.");
}
