//! Quickstart: compile the paper's LinReg DS script, look at every
//! compilation level (HOPs → runtime plan → costed plan), then execute a
//! real small instance end to end.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::collections::HashMap;

use systemds::api::{compile, CompileOptions, Scenario, LINREG_DS};
use systemds::conf::{ClusterConfig, CostConstants, MB};
use systemds::cost;
use systemds::cp::interp::Executor;
use systemds::matrix::{io, ops, DenseMatrix};
use systemds::runtime::KernelRegistry;
use systemds::util::error::{Error, Result};

fn main() -> Result<()> {
    // ---- 1. compile the paper's XS scenario against the paper's cluster
    let opts = CompileOptions::default();
    let xs = Scenario::xs();
    let compiled = xs.compile(&opts);

    println!("=== HOP EXPLAIN (paper Figure 1) ===");
    println!("{}", compiled.explain_hops(&opts));

    println!("=== Runtime plan (paper Figure 2) ===");
    println!("{}", compiled.explain_runtime());

    println!("=== Costed plan (paper Figure 4) ===");
    let report =
        cost::cost_program(&compiled.runtime, &opts.cfg, &opts.cc.0, &CostConstants::default());
    println!("{}", cost::explain_costed(&report));
    println!("estimated C(P,cc) = {:.2}s (paper: 3.31s)\n", report.total);

    // ---- 2. run a real instance: 2048x128 data on this machine
    let dir = std::env::temp_dir().join("sysds_quickstart");
    std::fs::create_dir_all(&dir)?;
    let x = DenseMatrix::rand(2048, 128, -1.0, 1.0, 1.0, 11);
    let beta_true = DenseMatrix::rand(128, 1, -0.5, 0.5, 1.0, 12);
    let y = ops::matmult(&x, &beta_true, 4);
    let xp = dir.join("X").to_string_lossy().to_string();
    let yp = dir.join("y").to_string_lossy().to_string();
    io::write_binary_block(&xp, &x, 1000)?;
    io::write_binary_block(&yp, &y, 1000)?;

    let mut args = HashMap::new();
    args.insert(1, xp);
    args.insert(2, yp);
    args.insert(3, "0".to_string());
    args.insert(4, dir.join("beta").to_string_lossy().to_string());

    let local = CompileOptions {
        cc: systemds::api::ClusterConfigOpt(ClusterConfig::local(8, 2048.0 * MB)),
        ..Default::default()
    };
    let prog = compile(LINREG_DS, &args, &local).map_err(Error::msg)?;
    let registry = KernelRegistry::load(std::path::Path::new("artifacts")).ok();
    let mut exec = Executor::new(&local.cfg, &local.cc.0, registry.as_ref(), dir.join("scratch"));
    let stats = exec.run(&prog.runtime)?;
    println!(
        "executed LinReg 2048x128: {} CP insts, {} PJRT kernel calls, {:.3}s",
        stats.cp_insts, stats.pjrt_calls, stats.elapsed_secs
    );

    let beta = io::read_matrix(args.get(&4).unwrap())?;
    let err = beta.max_abs_diff(&beta_true);
    println!("max |beta - beta_true| = {err:.2e} (lambda-regularised)");
    Ok(())
}
